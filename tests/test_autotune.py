"""MSF auto-tuner: the paper's manual sweep as an algorithm."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # optional dev dep: property tests skip
    from conftest import given, settings, st

from repro.config import SyncConfig
from repro.core import costmodel
from repro.core.autotune import (AdaptiveController, TuneInputs,
                                 choose_period, drift_cap,
                                 predicted_step_time, report, sync_time_s)
from repro.core.telemetry import BlockTelemetry


def _qwen3_2pod():
    """The §Perf cell-C numbers: 235B over 512 chips, 2-pod DCN sync."""
    return TuneInputs(
        param_bytes_per_chip=int(235e9 * 4 / 256),   # fp32 master, per chip
        replicas=2,
        step_time_s=0.090,        # ~compute-bound step at 256 chips/pod
        link_bw=6.25e9,
        grad_norm=1.0, param_norm=100.0, lr=3e-4)


class TestCostModel:
    def test_sync_time_matches_cell_c(self):
        """Analytic DCN sync ≈ the measured C0 per-sync wire time."""
        t = sync_time_s(_qwen3_2pod(), SyncConfig())
        # measured C0: 4.35 GB/step / 6.25 GB/s ≈ 0.70 s
        assert 0.4 < t < 0.8, t

    def test_compression_ordering(self):
        inp = _qwen3_2pod()
        t_fp32 = sync_time_s(inp, SyncConfig())
        t_int16 = sync_time_s(inp, SyncConfig(compression="int16"))
        t_int8 = sync_time_s(inp, SyncConfig(compression="int8"))
        assert t_int8 < t_int16 < t_fp32
        assert t_int16 == pytest.approx(t_fp32 / 2, rel=0.01)

    def test_overhead_meets_target(self):
        inp = _qwen3_2pod()
        cfg = SyncConfig(strategy="hierarchical")
        h = choose_period(inp, cfg, target_overhead=0.05, max_drift=1.0)
        overhead = sync_time_s(inp, cfg) / h / inp.step_time_s
        assert overhead <= 0.05
        # smallest such H: H−1 must violate the target
        if h > 1:
            assert sync_time_s(inp, cfg) / (h - 1) / inp.step_time_s > 0.05

    def test_drift_cap_binds(self):
        inp = TuneInputs(param_bytes_per_chip=10**9, replicas=2,
                         step_time_s=1e-4, link_bw=6.25e9,
                         grad_norm=10.0, param_norm=1.0, lr=1e-2)
        # huge comm need, but drift per step = 0.1 → cap at max_drift/0.1
        h = choose_period(inp, max_drift=0.01)
        assert h == drift_cap(inp, 0.01) == 1  # 0.01/0.1 < 1 → clamp to 1

    def test_predicted_time_monotone_in_h(self):
        inp = _qwen3_2pod()
        cfg = SyncConfig()
        ts = [predicted_step_time(inp, cfg, h) for h in (1, 2, 8, 64)]
        assert ts == sorted(ts, reverse=True)

    def test_report_shape(self):
        rep = report(_qwen3_2pod())
        assert rep["chosen_h"] >= 1
        assert set(rep) >= {"sync_time_s", "chosen_h", "ladder"}


@settings(deadline=None, max_examples=50)
@given(p=st.integers(10**6, 10**11), k=st.integers(2, 64),
       step=st.floats(1e-3, 10.0), bw=st.sampled_from([6.25e9, 50e9]))
def test_choose_period_properties(p, k, step, bw):
    """Property: chosen H always ≥1, and the resulting overhead is ≤ the
    target whenever the drift cap doesn't bind."""
    inp = TuneInputs(param_bytes_per_chip=p, replicas=k, step_time_s=step,
                     link_bw=bw, grad_norm=1e-6, param_norm=1.0, lr=1e-6)
    cfg = SyncConfig()
    h = choose_period(inp, cfg, target_overhead=0.1, max_drift=0.5)
    assert h >= 1
    assert sync_time_s(inp, cfg) / h / step <= 0.1 * 1.001


# ---------------------------------------------------------------------------
# choose_period monotonicity (ISSUE 3 satellite): H vs bandwidth, topology
# spectral-gap caps, delayed ≤ blocking
# ---------------------------------------------------------------------------

def _comm_bound(bw=6.25e9):
    """Comm-dominated inputs with a loose drift regime (cap ≫ 1)."""
    return TuneInputs(param_bytes_per_chip=10**9, replicas=8,
                      step_time_s=1e-3, link_bw=bw,
                      grad_norm=1e-6, param_norm=1.0, lr=1e-6)


class TestChoosePeriodMonotone:
    def test_h_non_increasing_in_bandwidth(self):
        """Faster fabric ⇒ smaller T_sync ⇒ the smallest-H-that-helps can
        only shrink — H is non-increasing in link bandwidth."""
        ladder = [1e9, 2e9, 6.25e9, 12.5e9, 50e9, 400e9]
        hs = [choose_period(_comm_bound(bw), SyncConfig(), max_drift=10.0)
              for bw in ladder]
        assert hs == sorted(hs, reverse=True), hs
        assert hs[0] > hs[-1]           # strictly smaller across the range

    @settings(deadline=None, max_examples=40)
    @given(p=st.integers(10**7, 10**11), k=st.integers(2, 64),
           step=st.floats(1e-4, 1.0),
           bw_lo=st.sampled_from([1e9, 6.25e9]),
           scale=st.floats(1.0, 100.0))
    def test_h_non_increasing_in_bandwidth_property(self, p, k, step,
                                                    bw_lo, scale):
        inp_lo = TuneInputs(param_bytes_per_chip=p, replicas=k,
                            step_time_s=step, link_bw=bw_lo,
                            grad_norm=1e-6, param_norm=1.0, lr=1e-6)
        inp_hi = TuneInputs(param_bytes_per_chip=p, replicas=k,
                            step_time_s=step, link_bw=bw_lo * scale,
                            grad_norm=1e-6, param_norm=1.0, lr=1e-6)
        cfg = SyncConfig()
        assert (choose_period(inp_hi, cfg, max_drift=10.0)
                <= choose_period(inp_lo, cfg, max_drift=10.0))

    @pytest.mark.parametrize("topology", ["ring", "pairwise"])
    def test_gossip_h_capped_by_spectral_gap(self, topology):
        """In the drift-bound regime a gossip topology's H must equal the
        blocking cap scaled by its spectral gap 1−λ₂ (sparser mixing ⇒
        tighter cap), and never exceed the topology='all' H."""
        inp = TuneInputs(param_bytes_per_chip=10**9, replicas=8,
                         step_time_s=1e-6, link_bw=1e6,   # comm-starved
                         grad_norm=1.0, param_norm=1.0, lr=1e-4)
        h_all = choose_period(inp, SyncConfig(topology="all"),
                              max_drift=0.05)
        h_topo = choose_period(inp, SyncConfig(topology=topology),
                               max_drift=0.05)
        gap = costmodel.spectral_gap(8, topology)
        cap = drift_cap(inp, 0.05)
        assert h_topo <= h_all
        assert h_topo == max(1, int(cap * gap))

    def test_cap_ordering_follows_spectral_gap(self):
        """Across topologies at the same K, the drift-bound H must order
        exactly as the spectral gaps do (slower mixing ⇒ tighter cap) —
        with topology='all' (gap 1) the loosest."""
        inp = TuneInputs(param_bytes_per_chip=10**9, replicas=8,
                         step_time_s=1e-6, link_bw=1e6,
                         grad_norm=1.0, param_norm=1.0, lr=1e-4)
        gaps = {t: costmodel.spectral_gap(8, t)
                for t in ("all", "ring", "pairwise")}
        hs = {t: choose_period(inp, SyncConfig(topology=t), max_drift=0.05)
              for t in ("all", "ring", "pairwise")}
        order_by_gap = sorted(gaps, key=gaps.get)
        order_by_h = sorted(hs, key=hs.get)
        assert order_by_gap == order_by_h, (gaps, hs)
        assert max(hs.values()) == hs["all"]

    @settings(deadline=None, max_examples=40)
    @given(p=st.integers(10**6, 10**11), k=st.integers(2, 64),
           step=st.floats(1e-3, 10.0), bw=st.sampled_from([6.25e9, 50e9]))
    def test_delayed_h_le_blocking_h_property(self, p, k, step, bw):
        """Delayed overlap only needs the collective to fit under the next
        block: its H is ≤ the blocking H at equal inputs, always."""
        inp = TuneInputs(param_bytes_per_chip=p, replicas=k,
                         step_time_s=step, link_bw=bw,
                         grad_norm=1e-6, param_norm=1.0, lr=1e-6)
        h_blk = choose_period(inp, SyncConfig(), max_drift=10.0)
        h_dly = choose_period(inp, SyncConfig(overlap="delayed"),
                              max_drift=10.0)
        assert h_dly <= h_blk


# ---------------------------------------------------------------------------
# telemetry + adaptive controller (ISSUE 3 tentpole, host-side half)
# ---------------------------------------------------------------------------

class TestBlockTelemetry:
    def test_direct_estimates(self):
        t = BlockTelemetry(warmup=0)
        for _ in range(4):
            t.record_step_time(2e-3)
            t.record_sync_time(5e-3)
        t_step, t_sync = t.estimates()
        assert t_step == pytest.approx(2e-3)
        assert t_sync == pytest.approx(5e-3)

    def test_warmup_discards_compile_sample(self):
        t = BlockTelemetry(warmup=1)
        t.record_step_time(10.0)       # compile-inflated, dropped
        t.record_sync_time(10.0)
        t.record_step_time(1e-3)
        t.record_sync_time(2e-3)
        t_step, t_sync = t.estimates()
        assert t_step == pytest.approx(1e-3)
        assert t_sync == pytest.approx(2e-3)

    def test_block_regression_separates_step_and_sync(self):
        """Whole-block times at two H's: y = T_step + T_sync/H recovers
        both parameters by least squares."""
        t = BlockTelemetry(warmup=0)
        t_step, t_sync = 1e-3, 8e-3
        for h in (4, 32):
            for _ in range(3):
                t.record_block(h, h * t_step + t_sync)
        est = t.estimates()
        assert est is not None
        assert est[0] == pytest.approx(t_step, rel=1e-6)
        assert est[1] == pytest.approx(t_sync, rel=1e-6)

    def test_single_h_insufficient_for_split(self):
        t = BlockTelemetry(warmup=0)
        t.record_block(8, 1.0)
        assert t.estimates() is None


def _ctrl(cfg=None, **kw):
    cfg = cfg or SyncConfig(strategy="periodic")
    kw.setdefault("param_bytes_per_chip", 10**8)
    kw.setdefault("replicas", 8)
    kw.setdefault("lr", 1e-6)
    return AdaptiveController(cfg, **kw)


class TestAdaptiveController:
    def test_resolves_only_every_adapt_every_blocks(self):
        c = _ctrl(h0=1, adapt_every=8)
        c.telemetry._skip_step = c.telemetry._skip_sync = 0
        for i in range(7):
            c.observe_block(step_s=1e-3, sync_s=8e-3)
            assert c.h == 1, i          # cadence not reached yet
        c.observe_block(step_s=1e-3, sync_s=8e-3)
        assert c.h > 1                  # 8th block triggered the re-solve

    def test_converges_to_analytic_h(self):
        """Fed exact (T_step, T_sync) telemetry, the controller lands on
        choose_period with the measured-sync override."""
        t_step, t_sync = 1e-3, 8e-3
        c = _ctrl(h0=1, adapt_every=4)
        c.telemetry._skip_step = c.telemetry._skip_sync = 0
        for _ in range(16):
            c.observe_block(step_s=t_step, sync_s=t_sync)
        inp = TuneInputs(param_bytes_per_chip=10**8, replicas=8,
                         step_time_s=t_step, grad_norm=1.0, param_norm=1.0,
                         lr=1e-6)
        want = choose_period(inp, SyncConfig(strategy="periodic"),
                             sync_time_override=t_sync)
        assert c.h == want

    def test_hysteresis_suppresses_small_moves(self):
        """A re-solve within the hysteresis band must not move H (every
        move recompiles the train block on the real path)."""
        c = _ctrl(h0=100, adapt_every=1, hysteresis=0.25)
        c.telemetry._skip_step = c.telemetry._skip_sync = 0
        # measurements that re-solve to 110: |110−100| < 0.25·100 ⇒ hold
        c.observe_block(step_s=1e-3, sync_s=110 * 0.05 * 1e-3)
        assert c.h == 100
        assert c.history == [(0, 100)]
        # a 4× jump clears the band and moves
        c.observe_block(step_s=1e-3, sync_s=400 * 0.05 * 1e-3)
        assert c.h != 100
        assert len(c.history) == 2

    def test_h_max_clamps_runaway(self):
        c = _ctrl(h0=1, adapt_every=1, h_max=64)
        c.telemetry._skip_step = c.telemetry._skip_sync = 0
        c.observe_block(step_s=1e-6, sync_s=10.0)   # absurd sync time
        assert c.h == 64

    def test_respects_gossip_spectral_cap(self):
        """The controller inherits choose_period's guardrails: with a
        drift-bound regime and a ring topology the re-solved H carries
        the spectral-gap cap."""
        cfg = SyncConfig(strategy="periodic", topology="ring")
        c = _ctrl(cfg=cfg, h0=1, adapt_every=1, lr=1e-2, max_drift=0.05)
        c.telemetry._skip_step = c.telemetry._skip_sync = 0
        c._grad_norm.update(1.0)
        c._param_norm.update(1.0)
        c.observe_block(step_s=1e-6, sync_s=1.0)    # comm wants huge H
        inp = TuneInputs(param_bytes_per_chip=10**8, replicas=8,
                         step_time_s=1e-6, grad_norm=1.0, param_norm=1.0,
                         lr=1e-2)
        want = choose_period(inp, cfg, max_drift=0.05,
                             sync_time_override=1.0)
        assert c.h == want
        assert c.h <= drift_cap(inp, 0.05)

    def test_no_move_before_estimates_exist(self):
        c = _ctrl(h0=4, adapt_every=1)
        c.observe_block(block_s=1.0)    # single H: split underdetermined
        assert c.h == 4


class TestTelemetryWiring:
    """The timed paths actually feed BlockTelemetry (ISSUE 3 layer 2)."""

    def test_svm_timed_steps_feed_split_estimates(self):
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.core import svm
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh((1,), ("data",))
        tel = BlockTelemetry(warmup=1)
        rng = np.random.default_rng(0)
        xb = jnp.asarray(rng.normal(size=(1, 4, 8)), jnp.float32)
        yb = jnp.ones((1, 4), jnp.float32)
        w0 = jnp.zeros(8)
        with jax.set_mesh(mesh):
            compute, sync = svm.dms_timed_steps(mesh, "data", block_size=4,
                                                telemetry=tel)
            for _ in range(3):
                wl = compute(w0, xb, yb, jnp.float32(0.5))
                sync(wl)
        est = tel.estimates()
        assert est is not None
        assert est[0] > 0 and est[1] > 0     # separated T_step / T_sync
        assert tel.n_syncs == 2              # warmup dropped the first

    def test_local_sgd_train_step_records_blocks(self):
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.config import (DataConfig, MeshConfig, OptimizerConfig,
                                  SyncConfig, TrainConfig, get_smoke)
        from repro.core import local_sgd as LS
        from repro.launch.mesh import make_test_mesh
        from repro.models.registry import build_model
        mesh = make_test_mesh((1, 1), ("data", "model"))
        cfg = TrainConfig(
            model=get_smoke("smollm-360m"),
            mesh=MeshConfig(shape=(1, 1), axis_names=("data", "model")),
            sync=SyncConfig(strategy="sync_every_step"),
            optimizer=OptimizerConfig(name="sgd", learning_rate=0.1),
            data=DataConfig(seq_len=8, global_batch=2))
        model = build_model(cfg.model)
        tel = BlockTelemetry(warmup=1)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, 64, (2, 8)),
                                       jnp.int32),
                 "targets": jnp.asarray(rng.integers(0, 64, (2, 8)),
                                        jnp.int32)}
        with jax.set_mesh(mesh):
            state = LS.init_state(model, cfg, jax.random.key(0))
            step = LS.make_train_step(model, cfg, mesh, telemetry=tel)
            for _ in range(3):
                state, _ = step(state, batch)
        # warmup dropped the compile call; the rest were recorded at H=1
        assert tel.n_blocks == 2

