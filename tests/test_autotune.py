"""MSF auto-tuner: the paper's manual sweep as an algorithm."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # optional dev dep: property tests skip
    from conftest import given, settings, st

from repro.config import SyncConfig
from repro.core.autotune import (TuneInputs, choose_period, drift_cap,
                                 predicted_step_time, report, sync_time_s)


def _qwen3_2pod():
    """The §Perf cell-C numbers: 235B over 512 chips, 2-pod DCN sync."""
    return TuneInputs(
        param_bytes_per_chip=int(235e9 * 4 / 256),   # fp32 master, per chip
        replicas=2,
        step_time_s=0.090,        # ~compute-bound step at 256 chips/pod
        link_bw=6.25e9,
        grad_norm=1.0, param_norm=100.0, lr=3e-4)


class TestCostModel:
    def test_sync_time_matches_cell_c(self):
        """Analytic DCN sync ≈ the measured C0 per-sync wire time."""
        t = sync_time_s(_qwen3_2pod(), SyncConfig())
        # measured C0: 4.35 GB/step / 6.25 GB/s ≈ 0.70 s
        assert 0.4 < t < 0.8, t

    def test_compression_ordering(self):
        inp = _qwen3_2pod()
        t_fp32 = sync_time_s(inp, SyncConfig())
        t_int16 = sync_time_s(inp, SyncConfig(compression="int16"))
        t_int8 = sync_time_s(inp, SyncConfig(compression="int8"))
        assert t_int8 < t_int16 < t_fp32
        assert t_int16 == pytest.approx(t_fp32 / 2, rel=0.01)

    def test_overhead_meets_target(self):
        inp = _qwen3_2pod()
        cfg = SyncConfig(strategy="hierarchical")
        h = choose_period(inp, cfg, target_overhead=0.05, max_drift=1.0)
        overhead = sync_time_s(inp, cfg) / h / inp.step_time_s
        assert overhead <= 0.05
        # smallest such H: H−1 must violate the target
        if h > 1:
            assert sync_time_s(inp, cfg) / (h - 1) / inp.step_time_s > 0.05

    def test_drift_cap_binds(self):
        inp = TuneInputs(param_bytes_per_chip=10**9, replicas=2,
                         step_time_s=1e-4, link_bw=6.25e9,
                         grad_norm=10.0, param_norm=1.0, lr=1e-2)
        # huge comm need, but drift per step = 0.1 → cap at max_drift/0.1
        h = choose_period(inp, max_drift=0.01)
        assert h == drift_cap(inp, 0.01) == 1  # 0.01/0.1 < 1 → clamp to 1

    def test_predicted_time_monotone_in_h(self):
        inp = _qwen3_2pod()
        cfg = SyncConfig()
        ts = [predicted_step_time(inp, cfg, h) for h in (1, 2, 8, 64)]
        assert ts == sorted(ts, reverse=True)

    def test_report_shape(self):
        rep = report(_qwen3_2pod())
        assert rep["chosen_h"] >= 1
        assert set(rep) >= {"sync_time_s", "chosen_h", "ladder"}


@settings(deadline=None, max_examples=50)
@given(p=st.integers(10**6, 10**11), k=st.integers(2, 64),
       step=st.floats(1e-3, 10.0), bw=st.sampled_from([6.25e9, 50e9]))
def test_choose_period_properties(p, k, step, bw):
    """Property: chosen H always ≥1, and the resulting overhead is ≤ the
    target whenever the drift cap doesn't bind."""
    inp = TuneInputs(param_bytes_per_chip=p, replicas=k, step_time_s=step,
                     link_bw=bw, grad_norm=1e-6, param_norm=1.0, lr=1e-6)
    cfg = SyncConfig()
    h = choose_period(inp, cfg, target_overhead=0.1, max_drift=0.5)
    assert h >= 1
    assert sync_time_s(inp, cfg) / h / step <= 0.1 * 1.001
