"""MSF sync engine: strategies, compression, slow momentum, byte model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # optional dev dep: property tests skip
    from conftest import given, settings, st

from repro.config import SyncConfig
from repro.core import compression as C
from repro.core import sync as S
from conftest import run_with_devices


class TestCompression:
    @settings(deadline=None, max_examples=20)
    @given(seed=st.integers(0, 1000), scale=st.floats(1e-3, 1e3))
    def test_quantize_roundtrip_error(self, seed, scale):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(257,)) * scale, jnp.float32)
        q, s = C.quantize(x)
        err = jnp.abs(C.dequantize(q, s) - x)
        assert float(jnp.max(err)) <= float(s) / 2 + 1e-6

    def test_error_feedback_is_lossless_over_time(self):
        """EF property: Σ_t dequant(q_t) converges to Σ_t delta_t — the
        residual stays bounded instead of accumulating bias."""
        rng = np.random.default_rng(0)
        deltas = [jnp.asarray(rng.normal(size=(64,)), jnp.float32)
                  for _ in range(50)]
        ef = {"w": jnp.zeros(64)}
        sent = jnp.zeros(64)
        for d in deltas:
            q, s, new_ef = C.compress_tree({"w": d}, ef)
            sent = sent + C.dequantize(q["w"], s["w"])
            ef = new_ef
        total = sum(deltas)
        # residual = total − sent = current EF buffer: bounded by one
        # quantization step, NOT growing with t
        resid = float(jnp.max(jnp.abs(total - sent)))
        assert resid < 0.2, resid

    def test_zero_delta(self):
        q, s = C.quantize(jnp.zeros(16))
        assert np.all(np.asarray(q) == 0)
        assert float(s) > 0


class TestSyncPoint:
    def _run_sync(self, cfg: SyncConfig, n_rep=4, d=32, seed=0):
        code = f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import sync as S
from repro.config import SyncConfig
cfg = SyncConfig(strategy="{cfg.strategy}", period={cfg.period},
                 compression="{cfg.compression}", slowmo={cfg.slowmo})
mesh = jax.make_mesh(({n_rep},), ("pod",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng({seed})
start = jnp.asarray(rng.normal(size=({d},)), jnp.float32)
ends = jnp.asarray(rng.normal(size=({n_rep}, {d})), jnp.float32)

def body(start, ends):
    p0 = {{"w": start}}
    p1 = {{"w": ends[0]}}
    st = S.init_sync_state(cfg, p0)
    new, _ = S.sync_point(p0, p1, st, cfg, "pod")
    return new["w"][None]

f = jax.shard_map(body, mesh=mesh, in_specs=(P(), P("pod")),
                  out_specs=P("pod"), axis_names={{"pod"}}, check_vma=False)
with jax.set_mesh(mesh):
    out = np.asarray(jax.jit(f)(start, ends))
expect = np.asarray(start) + (np.asarray(ends) - np.asarray(start)).mean(0)
err = np.abs(out - expect[None]).max()
print("ERR", err)
"""
        out = run_with_devices(code, n_devices=n_rep)
        return float(out.strip().split()[-1])

    def test_periodic_is_parameter_mean(self):
        err = self._run_sync(SyncConfig(strategy="periodic", period=4))
        assert err < 1e-6

    def test_int8_sync_close_to_mean(self):
        err = self._run_sync(SyncConfig(strategy="periodic", period=4,
                                        compression="int8"))
        assert err < 0.1   # one int8 quantization step of unit-scale data

    def test_int16_sync_close_to_mean(self):
        err = self._run_sync(SyncConfig(strategy="periodic", period=4,
                                        compression="int16"))
        assert err < 2e-3  # ~13-bit fixed point of unit-scale data

    def test_int16_world8_no_overflow_regression(self):
        """world ≥ 4 regression: the old fixed ±8192 clip made the int16
        psum wrap (4·8192 = 32768 > int16 max) whenever the replicas'
        quantized values aligned in sign — same-sign deltas at world=8
        summed to garbage. The headroom now scales with the replica count
        (qmax = 32767 // K), so the worst-case aligned sum stays in
        range."""
        code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import sync as S
from repro.config import SyncConfig
k, d = 8, 32
cfg = SyncConfig(strategy="periodic", period=4, compression="int16")
mesh = jax.make_mesh((k,), ("pod",),
                     axis_types=(jax.sharding.AxisType.Auto,))
start = jnp.zeros((d,), jnp.float32)
# identical ends on every replica: each quantizes to ±qmax exactly — the
# sign-aligned worst case that overflowed the old fixed-headroom psum
ends = jnp.broadcast_to(jnp.where(jnp.arange(d) % 2 == 0, 1.0, -1.0),
                        (k, d)).astype(jnp.float32)

def body(start, ends):
    p0 = {"w": start}
    p1 = {"w": ends[0]}
    st = S.init_sync_state(cfg, p0)
    new, _ = S.sync_point(p0, p1, st, cfg, "pod")
    return new["w"][None]

f = jax.shard_map(body, mesh=mesh, in_specs=(P(), P("pod")),
                  out_specs=P("pod"), axis_names={"pod"}, check_vma=False)
with jax.set_mesh(mesh):
    out = np.asarray(jax.jit(f)(start, ends))
expect = np.asarray(ends)          # mean of identical replicas
err = np.abs(out - expect).max()
print("ERR", err)
assert err < 2e-3, err
"""
        out = run_with_devices(code, n_devices=8)
        assert float(out.strip().split()[-1]) < 2e-3

    def test_state_axes_match_init(self):
        cfg = SyncConfig(strategy="periodic", compression="int8", slowmo=0.9)
        params = {"a": jnp.zeros((4, 8)), "b": jnp.zeros(3)}
        state = S.init_sync_state(cfg, params)
        axes = S.sync_state_axes(cfg, {"a": ("x", "y"), "b": ("z",)})
        assert jax.tree.structure(state) == jax.tree.structure(
            axes, is_leaf=lambda x: isinstance(x, tuple))


class TestByteModel:
    def test_amortized_bytes_scale_inverse_with_period(self):
        p = 10_000_000 * 4
        every = S.amortized_bytes_per_step(p, 16, SyncConfig())
        h8 = S.amortized_bytes_per_step(
            p, 16, SyncConfig(strategy="periodic", period=8))
        h64 = S.amortized_bytes_per_step(
            p, 16, SyncConfig(strategy="periodic", period=64))
        assert abs(every / h8 - 8) < 1e-6
        assert abs(every / h64 - 64) < 1e-6

    def test_int8_quarters_the_wire(self):
        p = 1_000_000 * 4
        fp = S.collective_bytes_per_sync(p, 2, SyncConfig())
        q8 = S.collective_bytes_per_sync(
            p, 2, SyncConfig(compression="int8"))
        assert q8 == pytest.approx(fp / 4, rel=0.01)


class TestLocalSGDBlock:
    def test_replicas_equal_after_sync_and_loss_falls(self):
        code = """
import jax, jax.numpy as jnp, numpy as np
from repro.config import (MeshConfig, ModelConfig, OptimizerConfig,
                          SyncConfig, TrainConfig, DataConfig, get_smoke)
from repro.core import local_sgd as LS
from repro.models.registry import build_model
from repro.sharding import rules_for
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
mesh_cfg = MeshConfig(shape=(2, 2, 2), axis_names=("pod", "data", "model"),
                      replica_axis="pod")
cfg = TrainConfig(
    model=get_smoke("internlm2-1.8b"),
    mesh=mesh_cfg,
    sync=SyncConfig(strategy="hierarchical", period=3),
    optimizer=OptimizerConfig(name="sgd", learning_rate=0.1),
    data=DataConfig(seq_len=16, global_batch=8))
model = build_model(cfg.model)
with jax.set_mesh(mesh):
    state = LS.init_state(model, cfg, jax.random.key(0), replicas=2)
    step = LS.make_local_sgd_block(model, cfg, mesh)
    rng = np.random.default_rng(0)
    fixed = {
        "tokens": jnp.asarray(rng.integers(0, 512, (3, 8, 16)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, 512, (3, 8, 16)), jnp.int32)}
    losses = []
    for i in range(4):
        state, metrics = jax.jit(step)(state, fixed)  # memorize one batch
        losses.append(float(metrics["loss"]))
# replicas must be byte-identical after the sync point
p = jax.device_get(state["params"])
for leaf in jax.tree.leaves(p):
    np.testing.assert_array_equal(leaf[0], leaf[1])
assert losses[-1] < losses[0], losses
assert int(jax.device_get(state["step"])) == 12  # 4 blocks × H=3
print("OK", losses[0], losses[-1])
"""
        out = run_with_devices(code, n_devices=8)
        assert "OK" in out

    def test_int8_hierarchical_block_runs(self):
        code = """
import jax, jax.numpy as jnp, numpy as np
from repro.config import (MeshConfig, OptimizerConfig, SyncConfig,
                          TrainConfig, DataConfig, get_smoke)
from repro.core import local_sgd as LS
from repro.models.registry import build_model
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
mesh_cfg = MeshConfig(shape=(2, 2, 2), axis_names=("pod", "data", "model"),
                      replica_axis="pod")
cfg = TrainConfig(
    model=get_smoke("smollm-360m"), mesh=mesh_cfg,
    sync=SyncConfig(strategy="hierarchical", period=2, compression="int8",
                    slowmo=0.5),
    optimizer=OptimizerConfig(name="momentum", learning_rate=0.05),
    data=DataConfig(seq_len=16, global_batch=8))
model = build_model(cfg.model)
with jax.set_mesh(mesh):
    state = LS.init_state(model, cfg, jax.random.key(0), replicas=2)
    step = LS.make_local_sgd_block(model, cfg, mesh)
    rng = np.random.default_rng(0)
    b = {"tokens": jnp.asarray(rng.integers(0, 512, (2, 8, 16)), jnp.int32),
         "targets": jnp.asarray(rng.integers(0, 512, (2, 8, 16)), jnp.int32)}
    for _ in range(2):
        state, metrics = jax.jit(step)(state, b)
    assert np.isfinite(float(metrics["loss"]))
print("OK")
"""
        out = run_with_devices(code, n_devices=8)
        assert "OK" in out
