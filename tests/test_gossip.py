"""Gossip (ring/pairwise) sync: consensus, collectives, bytes, guardrail.

ISSUE 2's contracts:

* Gossip mixing is doubly stochastic: the replica mean is invariant and
  the disagreement contracts within the spectral bound (ring: exactly λ₂
  per round — the mixing matrix is symmetric, so the operator norm on the
  mean-zero subspace IS λ₂).
* ``topology="ring"``/``"pairwise"`` emit ``ppermute``s and NO global
  collective (psum / all-gather / pmax) — verifiable from the jaxpr; under
  ``overlap="delayed"`` no dot consumes the ppermute output either (the
  gossip analog of the PR 1 overlap property).
* The vmap simulation (static mixing matrices) and the shard_map backend
  (real ppermutes) agree for every topology × overlap combination.
* ``collective_bytes_per_sync``, ``costmodel.wire_bytes_per_sync`` and the
  autotuner's ``sync_time_s`` agree under each topology; ring bytes are
  O(1) in the replica count.
* ``choose_period`` caps gossip H by the topology's spectral gap.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SyncConfig
from repro.core import costmodel
from repro.core import svm
from repro.core import sync as S
from repro.core.autotune import TuneInputs, choose_period, drift_cap, sync_time_s
from conftest import run_with_devices


# ---------------------------------------------------------------------------
# mixing matrices and spectra
# ---------------------------------------------------------------------------

class TestMixingSpectra:
    @pytest.mark.parametrize("topology", ["all", "ring", "pairwise"])
    @pytest.mark.parametrize("k", [2, 4, 8, 16])
    def test_matrices_doubly_stochastic(self, topology, k):
        for m in costmodel.mixing_matrices(k, topology):
            np.testing.assert_allclose(m.sum(0), 1.0, atol=1e-12)
            np.testing.assert_allclose(m.sum(1), 1.0, atol=1e-12)
            assert (m >= 0).all()

    def test_lambda2_ring_matches_circulant_analytic(self):
        """Ring eigenvalues are (1 + 2cos(2πm/K))/3 — λ₂ is the largest
        modulus over m ≠ 0."""
        for k in (2, 3, 4, 8, 16, 32):
            want = max(abs(1 + 2 * np.cos(2 * np.pi * m / k)) / 3
                       for m in range(1, k))
            got = costmodel.gossip_lambda2(k, "ring")
            assert got == pytest.approx(want, abs=1e-9), k

    def test_lambda2_all_is_zero(self):
        for k in (2, 8, 64):
            assert costmodel.gossip_lambda2(k, "all") == 0.0
            assert costmodel.spectral_gap(k, "all") == 1.0

    def test_lambda2_pairwise_small_worlds_mix_exactly(self):
        """K ≤ 4: the two alternating pairings reach exact consensus in one
        schedule period, so the asymptotic per-round rate is 0."""
        assert costmodel.gossip_lambda2(2, "pairwise") == pytest.approx(
            0.0, abs=1e-6)
        assert costmodel.gossip_lambda2(4, "pairwise") == pytest.approx(
            0.0, abs=1e-6)
        assert costmodel.gossip_lambda2(8, "pairwise") == pytest.approx(
            np.sqrt(0.5), abs=1e-6)

    def test_lambda2_grows_with_world(self):
        """Sparser relative connectivity ⇒ slower mixing."""
        lams = [costmodel.gossip_lambda2(k, "ring") for k in (4, 8, 16, 32)]
        assert lams == sorted(lams)
        assert all(0.0 <= l < 1.0 for l in lams)

    def test_pairwise_odd_world_rejected(self):
        with pytest.raises(ValueError):
            costmodel.mixing_matrices(5, "pairwise")


# ---------------------------------------------------------------------------
# consensus semantics (real ppermutes, subprocess mesh)
# ---------------------------------------------------------------------------

class TestGossipConsensus:
    def test_ring_contracts_within_spectral_bound_and_mean_invariant(self):
        """With zero drift, repeated ring sync_points must (i) keep the
        replica mean bit-stable, (ii) contract the disagreement by ≤ λ₂
        per round, (iii) converge to the global mean."""
        code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import sync as S
from repro.core import costmodel
from repro.config import SyncConfig
k, d, rounds = 8, 16, 12
cfg = SyncConfig(strategy="periodic", topology="ring")
mesh = jax.make_mesh((k,), ("pod",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
vals = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)

def body(v):
    p = {"w": v[0]}
    st = S.init_sync_state(cfg, p)
    outs = []
    for _ in range(rounds):
        p, st = S.sync_point(p, p, st, cfg, "pod")
        outs.append(p["w"])
    return jnp.stack(outs)[None]

f = jax.shard_map(body, mesh=mesh, in_specs=(P("pod"),),
                  out_specs=P("pod"), axis_names={"pod"}, check_vma=False)
with jax.set_mesh(mesh):
    out = np.asarray(jax.jit(f)(vals))       # (k, rounds, d)
base = np.asarray(vals)
mean = base.mean(0)
lam2 = costmodel.gossip_lambda2(k, "ring")
dis_prev = np.linalg.norm(base - mean)
for r in range(rounds):
    np.testing.assert_allclose(out[:, r].mean(0), mean, rtol=2e-5,
                               atol=2e-6)   # mean invariant
    dis = np.linalg.norm(out[:, r] - mean)
    assert dis <= lam2 * dis_prev * 1.001 + 1e-6, (r, dis, dis_prev)
    dis_prev = dis
assert dis_prev <= (lam2 ** rounds) * np.linalg.norm(base - mean) * 1.01 \
       + 1e-5
print("OK")
"""
        assert "OK" in run_with_devices(code, n_devices=8)

    def test_pairwise_contracts_within_product_operator_norm(self):
        """Pairwise rounds alternate pairings; per schedule period (2
        rounds) the worst-case contraction is ‖W_odd W_even − J‖₂."""
        code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import sync as S
from repro.core import costmodel
from repro.config import SyncConfig
k, d, periods = 8, 16, 5
cfg = SyncConfig(strategy="periodic", topology="pairwise")
mesh = jax.make_mesh((k,), ("pod",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(1)
vals = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)

def body(v):
    p = {"w": v[0]}
    st = S.init_sync_state(cfg, p)
    outs = []
    for _ in range(2 * periods):
        p, st = S.sync_point(p, p, st, cfg, "pod")
        outs.append(p["w"])
    return jnp.stack(outs)[None]

f = jax.shard_map(body, mesh=mesh, in_specs=(P("pod"),),
                  out_specs=P("pod"), axis_names={"pod"}, check_vma=False)
with jax.set_mesh(mesh):
    out = np.asarray(jax.jit(f)(vals))
base = np.asarray(vals)
mean = base.mean(0)
we, wo = costmodel.mixing_matrices(k, "pairwise")
opnorm = np.linalg.norm(wo @ we - np.full((k, k), 1.0 / k), 2)
dis_prev = np.linalg.norm(base - mean)
for r in range(periods):
    dis = np.linalg.norm(out[:, 2 * r + 1] - mean)
    assert dis <= opnorm * dis_prev * 1.001 + 1e-6, (r, dis, dis_prev)
    dis_prev = dis
np.testing.assert_allclose(out[:, -1].mean(0), mean, rtol=2e-5, atol=2e-6)
print("OK")
"""
        assert "OK" in run_with_devices(code, n_devices=8)

    def test_vmap_matches_shard_map_all_topologies_overlaps(self):
        """Static-matrix simulation ≡ real ppermute collectives."""
        code = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import svm
from repro.launch.mesh import make_test_mesh
rng = np.random.default_rng(0)
x = rng.normal(size=(256, 12)).astype(np.float32)
y = np.where(rng.random(256) > 0.5, 1.0, -1.0).astype(np.float32)
w0 = jnp.zeros(12)
mesh = make_test_mesh((8,), ("data",))
for topo in ("ring", "pairwise"):
    for ov in ("none", "delayed", "chunked"):
        wv = svm.dms(w0, x, y, workers=8, epochs=3, block_size=4,
                     overlap=ov, topology=topo)
        with jax.set_mesh(mesh):
            ws = svm.dms(w0, x, y, workers=8, epochs=3, block_size=4,
                         backend="shard_map", mesh=mesh, overlap=ov,
                         topology=topo)
        np.testing.assert_allclose(np.asarray(wv), np.asarray(ws),
                                   rtol=1e-5, atol=1e-6, err_msg=f"{topo}/{ov}")
print("OK")
"""
        assert "OK" in run_with_devices(code, n_devices=8)

    def test_gossip_compressed_sync_reaches_mean(self):
        """int8/int16 gossip wires (per-sender scale, EF residual) still
        drive the replicas to the global mean with zero drift."""
        code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import sync as S
from repro.config import SyncConfig
k, d = 4, 32
mesh = jax.make_mesh((k,), ("pod",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
vals = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
for topo in ("ring", "pairwise"):
    for comp, tol in (("int16", 1e-3), ("int8", 2e-2)):
        cfg = SyncConfig(strategy="periodic", topology=topo,
                         compression=comp)
        def body(v):
            p = {"w": v[0]}
            st = S.init_sync_state(cfg, p)
            for _ in range(16):
                p, st = S.sync_point(p, p, st, cfg, "pod")
            return p["w"][None]
        f = jax.shard_map(body, mesh=mesh, in_specs=(P("pod"),),
                          out_specs=P("pod"), axis_names={"pod"},
                          check_vma=False)
        with jax.set_mesh(mesh):
            out = np.asarray(jax.jit(f)(vals))
        mean = np.asarray(vals).mean(0)
        err = np.abs(out - mean).max()
        assert err < tol, (topo, comp, err)
print("OK")
"""
        assert "OK" in run_with_devices(code, n_devices=4)

    def test_ring_converges_on_ijcnn(self, ijcnn_small):
        ds = ijcnn_small
        for topo in ("ring", "pairwise"):
            w = svm.dms(jnp.zeros(ds.features), ds.x_train, ds.y_train,
                        workers=8, epochs=20, block_size=16, topology=topo)
            acc = float(svm.accuracy(w, jnp.asarray(ds.x_cv),
                                     jnp.asarray(ds.y_cv)))
            assert acc > 0.75, (topo, acc)

    def test_pairwise_odd_axis_rejected_at_trace(self):
        with pytest.raises(ValueError):
            jax.make_jaxpr(
                lambda x: S.gossip_mix(x, "pod", "pairwise", round_idx=0),
                axis_env=[("pod", 3)])(jnp.zeros(4))

    def test_pairwise_without_round_rejected(self):
        """A frozen pairing would converge each disjoint pair to its own
        mean — gossip_mix must refuse rather than mix wrongly."""
        with pytest.raises(ValueError, match="round"):
            jax.make_jaxpr(
                lambda x: S.gossip_mix(x, "pod", "pairwise"),
                axis_env=[("pod", 4)])(jnp.zeros(4))

    def test_slowmo_gossip_rejected(self):
        with pytest.raises(ValueError):
            S.validate(SyncConfig(topology="ring", slowmo=0.5))


# ---------------------------------------------------------------------------
# the gossip property, mechanically: jaxpr primitive analysis
# ---------------------------------------------------------------------------

def _collect_prims(jaxpr, acc=None):
    """All primitive names, recursing into cond/scan/switch sub-jaxprs."""
    acc = set() if acc is None else acc
    for eqn in jaxpr.eqns:
        acc.add(eqn.primitive.name)
        for p in eqn.params.values():
            objs = p if isinstance(p, (list, tuple)) else (p,)
            for o in objs:
                sub = getattr(o, "jaxpr", None)
                if sub is not None:
                    _collect_prims(sub, acc)
    return acc


GLOBAL_COLLECTIVES = ("psum", "all_gather", "all_reduce", "pmax", "pmin",
                      "all_to_all")


def _block_jaxpr(topology: str, overlap: str, k: int = 8, d: int = 8):
    blockfn = svm._make_worker_block("pod", c=1.0, grad_impl="jnp",
                                     overlap=overlap, chunks=2, d=d,
                                     topology=topology)
    dp = -(-d // 2) * 2 if overlap == "chunked" else d
    carry = {"w": jnp.zeros(dp)}
    if overlap == "delayed":
        carry["pending"] = jnp.zeros(d)
    if overlap == "chunked" or topology == "pairwise":
        carry["cnt"] = jnp.zeros((), jnp.int32)
    xb, yb = jnp.zeros((4, d)), jnp.zeros((4,))
    return jax.make_jaxpr(
        lambda c, x, y: blockfn(c, x, y, 0.5),
        axis_env=[("pod", k)])(carry, xb, yb).jaxpr


class TestGossipEmitsNoGlobalCollective:
    @pytest.mark.parametrize("topology", ["ring", "pairwise"])
    @pytest.mark.parametrize("overlap", ["none", "delayed", "chunked"])
    def test_gossip_block_is_ppermute_only(self, topology, overlap):
        prims = _collect_prims(_block_jaxpr(topology, overlap))
        assert "ppermute" in prims, prims
        bad = {p for p in prims
               if any(p.startswith(g) for g in GLOBAL_COLLECTIVES)}
        assert not bad, bad

    def test_all_block_has_global_collective_sanity(self):
        prims = _collect_prims(_block_jaxpr("all", "none"))
        assert any(p.startswith("psum") for p in prims), prims
        assert "ppermute" not in prims

    def test_delayed_gossip_ppermute_feeds_no_dot(self):
        """Across two chained delayed-ring blocks no dot_general consumes a
        ppermute output — the gossip exchange only flows into the carried
        pending correction, so it can run under the next block's compute
        (the PR 1 overlap property, gossip edition)."""
        from test_overlap import _collective_taints_dot
        d, bs = 8, 4
        blockfn = svm._make_worker_block("pod", c=1.0, grad_impl="jnp",
                                         overlap="delayed", chunks=2, d=d,
                                         topology="ring")
        carry = {"w": jnp.zeros(d), "pending": jnp.zeros(d)}
        xb, yb = jnp.zeros((bs, d)), jnp.zeros((bs,))

        def two_blocks(carry, x1, y1, x2, y2):
            c1 = blockfn(carry, x1, y1, 0.5)
            return blockfn(c1, x2, y2, 0.5)

        jaxpr = jax.make_jaxpr(two_blocks, axis_env=[("pod", 8)])(
            carry, xb, yb, xb, yb).jaxpr
        assert not _collective_taints_dot(jaxpr, source_prim="ppermute")


# ---------------------------------------------------------------------------
# byte accounting + the autotuner guardrail
# ---------------------------------------------------------------------------

class TestGossipBytes:
    def test_ring_bytes_independent_of_world(self):
        """Acceptance: ring moves O(1) neighbor bytes per sync vs the
        all-reduce's 2P(K−1)/K."""
        p = 8_000_000
        ring = [S.collective_bytes_per_sync(
            p, k, SyncConfig(topology="ring")) for k in (2, 4, 16, 64)]
        assert len(set(ring)) == 1
        assert ring[0] == 2 * p
        allred = [S.collective_bytes_per_sync(p, k, SyncConfig())
                  for k in (2, 4, 16, 64)]
        assert allred == sorted(allred)          # grows with K
        assert ring[0] == pytest.approx(2 * p)   # vs 2P(K−1)/K → 2P

    def test_pairwise_halves_ring_bytes(self):
        p = 1_000_000
        ring = S.collective_bytes_per_sync(p, 8, SyncConfig(topology="ring"))
        pair = S.collective_bytes_per_sync(
            p, 8, SyncConfig(topology="pairwise"))
        assert pair == pytest.approx(ring / 2)

    @pytest.mark.parametrize("topology", ["all", "ring", "pairwise"])
    @pytest.mark.parametrize("compression", ["none", "int8", "int16"])
    @pytest.mark.parametrize("overlap", ["none", "delayed", "chunked"])
    def test_bytes_and_tuner_agree_per_topology(self, topology, compression,
                                                overlap):
        """collective_bytes_per_sync ≡ wire_bytes_per_sync ≡ sync_time·BW
        for every (topology × compression × overlap) cell."""
        cfg = SyncConfig(strategy="periodic", period=8, topology=topology,
                         compression=compression, overlap=overlap, chunks=4)
        for k in (2, 4, 16):
            p = 10_000_000
            inp = TuneInputs(param_bytes_per_chip=p, replicas=k,
                             step_time_s=0.09, link_bw=1e9,
                             grad_norm=1.0, param_norm=100.0, lr=3e-4)
            from_tuner = sync_time_s(inp, cfg) * inp.link_bw
            from_sync = S.collective_bytes_per_sync(p, k, cfg)
            assert from_sync == pytest.approx(from_tuner, rel=1e-9, abs=1.0)
            assert from_sync == pytest.approx(
                costmodel.wire_bytes_per_sync(p, k, cfg), rel=1e-9, abs=1.0)

    def test_gossip_compression_scales_payload(self):
        p = 4_000_000
        fp = S.collective_bytes_per_sync(p, 8, SyncConfig(topology="ring"))
        i16 = S.collective_bytes_per_sync(
            p, 8, SyncConfig(topology="ring", compression="int16"))
        i8 = S.collective_bytes_per_sync(
            p, 8, SyncConfig(topology="ring", compression="int8"))
        assert i16 == pytest.approx(fp / 2)
        assert i8 == pytest.approx(fp / 4)


class TestSpectralGuardrail:
    def _inp(self, k=8):
        # huge comm pressure so h_comm is large and the drift cap binds
        return TuneInputs(param_bytes_per_chip=10**12, replicas=k,
                          step_time_s=1e-4, link_bw=6.25e9,
                          grad_norm=1.0, param_norm=100.0, lr=1e-3)

    def test_gossip_h_capped_by_spectral_gap(self):
        inp = self._inp()
        cap = drift_cap(inp, 0.01)
        assert cap > 4
        for topo in ("ring", "pairwise"):
            cfg = SyncConfig(strategy="periodic", topology=topo)
            h = choose_period(inp, cfg, target_overhead=0.05, max_drift=0.01)
            gap = costmodel.spectral_gap(8, topo)
            assert h == max(1, int(cap * gap)), (topo, h, cap, gap)

    def test_gossip_h_never_exceeds_all(self):
        for k in (2, 4, 8, 16):
            inp = self._inp(k)
            h_all = choose_period(inp, SyncConfig(strategy="periodic"),
                                  max_drift=0.01)
            for topo in ("ring", "pairwise"):
                h = choose_period(
                    inp, SyncConfig(strategy="periodic", topology=topo),
                    max_drift=0.01)
                assert 1 <= h <= h_all, (k, topo, h, h_all)

    def test_h_ordering_follows_spectral_gap(self):
        """The faster mixer gets the larger H. At K=8 the alternating
        pairwise schedule (λ₂=√½≈0.71) out-mixes the static ring
        (λ₂≈0.80) despite moving half the bytes — the guardrail must rank
        them by gap, not by degree."""
        inp = self._inp(8)
        h_ring = choose_period(
            inp, SyncConfig(strategy="periodic", topology="ring"),
            max_drift=0.01)
        h_pair = choose_period(
            inp, SyncConfig(strategy="periodic", topology="pairwise"),
            max_drift=0.01)
        assert costmodel.spectral_gap(8, "pairwise") > costmodel.spectral_gap(
            8, "ring")
        assert h_pair >= h_ring
