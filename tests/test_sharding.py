"""Logical-axis sharding rules: divisibility fallback, axis dedup, remap."""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # optional dev dep: property tests skip
    from conftest import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.config import MeshConfig
from repro.sharding import DEFAULT_RULES, rules_for


class FakeMesh:
    """axis_names/devices.shape stand-in (no real devices needed)."""

    def __init__(self, shape, names):
        self.axis_names = names
        import numpy as np
        self.devices = np.zeros(shape)


def _rules(shape=(16, 16), names=("data", "model")):
    cfg = MeshConfig(shape=shape, axis_names=names,
                     replica_axis="pod" if "pod" in names else "")
    return rules_for(cfg, FakeMesh(shape, names))


def _pad(spec, n):
    t = tuple(spec)
    return t + (None,) * (n - len(t))


class TestSpecFor:
    def test_basic_mapping(self):
        r = _rules()
        spec = _pad(r.spec_for(("batch", "seq", "embed"),
                               (256, 4096, 1024)), 3)
        # batch→data; seq unsharded; embed→data dropped (axis already used)
        assert spec == ("data", None, None)

    def test_divisibility_fallback(self):
        r = _rules()
        # 15 heads do not divide the 16-way model axis → replicate
        spec = _pad(r.spec_for(("layers", "embed", "heads", "head_dim"),
                               (32, 960, 15, 64)), 4)
        assert spec[2] is None
        # 32 heads divide → sharded
        spec = _pad(r.spec_for(("layers", "embed", "heads", "head_dim"),
                               (32, 960, 32, 64)), 4)
        assert spec[2] == "model"

    def test_axis_used_once(self):
        r = _rules()
        # kv_heads grabs model; q_group must not reuse it
        spec = r.spec_for(("batch", "kv_heads", "q_group"), (16, 32, 16))
        entries = [e for e in spec if e is not None]
        flat = []
        for e in entries:
            flat.extend(e if isinstance(e, tuple) else (e,))
        assert len(flat) == len(set(flat))

    def test_gqa_preference_order(self):
        r = _rules()
        # kv=4 does not divide 16 → q_group (16) takes the model axis
        spec = r.spec_for(("batch", "kv_heads", "q_group", "seq"),
                          (16, 4, 16, 512))
        assert spec[1] is None and spec[2] == "model"

    def test_tokens_two_axis_sharding(self):
        r = _rules()
        spec = r.spec_for(("tokens", None), (1048576, 4096))
        assert spec[0] == ("data", "model")

    def test_missing_axis_dropped_on_single_pod(self):
        r = _rules()           # no pod axis in mesh
        spec = r.spec_for(("replica", "embed"), (2, 1024))
        assert spec == P(None, "data")

    def test_multi_pod_replica(self):
        r = _rules((2, 16, 16), ("pod", "data", "model"))
        spec = r.spec_for(("replica", "embed"), (2, 1024))
        assert spec == P("pod", "data")


@settings(deadline=None, max_examples=100)
@given(
    logical=st.lists(st.sampled_from(list(DEFAULT_RULES) + [None]),
                     min_size=1, max_size=5),
    dims=st.lists(st.sampled_from([1, 2, 3, 15, 16, 30, 32, 256]),
                  min_size=5, max_size=5),
)
def test_spec_always_valid(logical, dims):
    """Property: any (logical axes × shape) yields a valid PartitionSpec —
    every mesh axis used at most once, sharded dims always divisible."""
    r = _rules()
    shape = tuple(dims[:len(logical)])
    spec = r.spec_for(tuple(logical), shape)
    used = []
    sizes = {"data": 16, "model": 16}
    for i, entry in enumerate(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        used.extend(axes)
        total = 1
        for a in axes:
            total *= sizes[a]
        assert shape[i] % total == 0, (spec, shape)
    assert len(used) == len(set(used)), spec
