"""simsync: discrete-event simulator + adaptive MSF controller (ISSUE 3).

Covers the tentpole's acceptance criteria as property tests:
* simulated comm time ∝ 1/H with ≥ 10x reduction between the highest- and
  lowest-MSF schedules on the default DCN profile;
* the adaptive controller converges within 20% of the simulator's
  oracle-optimal H on at least two distinct cluster profiles;
plus schedule semantics (straggler decoupling of gossip vs all-reduce,
delayed-overlap exposure, chunked wire scaling), determinism, profile
round-trip, and Chrome-trace validity. Pure numpy — no jax, fast.
"""
import json

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # optional dev dep: property tests skip
    from conftest import given, settings, st

from repro.config import SyncConfig
from repro.core.autotune import AdaptiveController
from repro.simsync import (PROFILES, ClusterProfile, ClusterSim,
                           chrome_trace, dcn_profile, ici_profile, oracle_h,
                           simulate, simulate_adaptive, sync_wire_time_s,
                           uniform_profile)

CFG = SyncConfig(strategy="periodic")


def _quiet_dcn(**kw):
    """Jitter-free DCN profile: exact comm ∝ 1/H arithmetic."""
    return dcn_profile(jitter=0.0, name="dcn_quiet", **kw)


class TestDeterminismAndProfiles:
    def test_same_seed_same_result(self):
        a = simulate(PROFILES["dcn_transient"], CFG, h=8, steps=512, seed=3)
        b = simulate(PROFILES["dcn_transient"], CFG, h=8, steps=512, seed=3)
        assert a.wall_clock_s == b.wall_clock_s
        assert a.comm_exposed_s == b.comm_exposed_s

    def test_profile_dict_roundtrip(self):
        p = PROFILES["dcn_straggler"]
        q = ClusterProfile.from_dict(p.to_dict())
        assert q == p

    def test_pairwise_needs_even_world(self):
        p = uniform_profile("odd", 3, step_time=1e-3, jitter=0.0,
                            bandwidth=1e9, latency=0.0, param_bytes=1000)
        with pytest.raises(ValueError):
            ClusterSim(p, SyncConfig(strategy="periodic",
                                     topology="pairwise"))


class TestCommVsH:
    """Acceptance: comm time ∝ 1/H, ≥ 10x reduction on the DCN profile."""

    def test_comm_inverse_in_h_exact_without_jitter(self):
        prof = _quiet_dcn()
        steps = 1024
        ref = simulate(prof, CFG, h=1, steps=steps, seed=0)
        for h in (2, 4, 8, 16, 32, 64):
            r = simulate(prof, CFG, h=h, steps=steps, seed=0)
            # fixed work ⇒ syncs = steps/H ⇒ total comm scales exactly 1/H
            assert r.comm_exposed_s == pytest.approx(
                ref.comm_exposed_s / h, rel=1e-9)
            assert r.comm_wire_s == pytest.approx(
                ref.comm_wire_s / h, rel=1e-9)

    def test_ge_10x_reduction_on_default_dcn(self):
        prof = PROFILES["dcn_default"]
        hi = simulate(prof, CFG, h=1, steps=2048, seed=0)
        lo = simulate(prof, CFG, h=64, steps=2048, seed=0)
        assert hi.comm_exposed_s / lo.comm_exposed_s >= 10.0
        # the paper's 16x–24x regime sits inside the ladder: H=16..24 give
        # 16x–24x fewer syncs, i.e. comm within ~±jitter of that factor
        mid = simulate(prof, CFG, h=16, steps=2048, seed=0)
        assert hi.comm_exposed_s / mid.comm_exposed_s == pytest.approx(
            16.0, rel=0.25)

    @settings(deadline=None, max_examples=25)
    @given(h1=st.integers(1, 64), h2=st.integers(1, 64),
           seed=st.integers(0, 10))
    def test_comm_ratio_property(self, h1, h2, seed):
        """For any H pair on the (jitter-free) DCN profile the comm ratio
        is exactly h2/h1 — the ∝ 1/H law as a property."""
        prof = _quiet_dcn()
        a = simulate(prof, CFG, h=h1, steps=512, seed=seed)
        b = simulate(prof, CFG, h=h2, steps=512, seed=seed)
        # block counts are floor(steps/h): compare per-executed-sync comm
        ca = a.comm_exposed_s / a.blocks
        cb = b.comm_exposed_s / b.blocks
        assert ca == pytest.approx(cb, rel=1e-9)   # comm per sync constant
        assert (a.comm_exposed_s * a.steps / a.blocks == pytest.approx(
            b.comm_exposed_s * b.steps / b.blocks * (a.steps / b.steps),
            rel=1e-6))

    def test_wall_clock_monotone_nonincreasing_in_h(self):
        prof = PROFILES["dcn_default"]
        walls = [simulate(prof, CFG, h=h, steps=2048, seed=0).wall_clock_s
                 for h in (1, 4, 16, 64)]
        assert walls == sorted(walls, reverse=True)


class TestScheduleSemantics:
    def test_delayed_exposes_less_than_blocking(self):
        prof = PROFILES["dcn_default"]
        for topo in ("all", "ring"):
            blk = simulate(prof, SyncConfig(strategy="periodic",
                                            topology=topo), h=16,
                           steps=1024, seed=0)
            dly = simulate(prof, SyncConfig(strategy="periodic",
                                            topology=topo,
                                            overlap="delayed"), h=16,
                           steps=1024, seed=0)
            assert dly.comm_exposed_s < blk.comm_exposed_s
        # when T_sync < H·T_step the delayed collective fully hides
        assert dly.comm_exposed_s < 0.05 * dly.compute_s

    def test_chunked_divides_wire_time(self):
        prof = _quiet_dcn()
        t_full = sync_wire_time_s(prof, SyncConfig())
        t_chunk = sync_wire_time_s(prof, SyncConfig(overlap="chunked",
                                                    chunks=4))
        # latency is per-collective; the wire term divides by the shards
        lat = prof.link.latency * 2 * (prof.world - 1)
        assert (t_chunk - lat) == pytest.approx((t_full - lat) / 4,
                                                rel=1e-9)

    def test_gossip_wire_time_o1_in_k(self):
        t8 = sync_wire_time_s(dcn_profile(8, jitter=0.0),
                              SyncConfig(topology="ring"))
        t64 = sync_wire_time_s(dcn_profile(64, jitter=0.0),
                               SyncConfig(topology="ring"))
        assert t8 == pytest.approx(t64, rel=1e-9)

    def test_straggler_decoupling_gossip_vs_allreduce(self):
        """ROADMAP's unmeasurable effect: under delayed overlap a transient
        straggle stalls every worker behind the global barrier but only a
        decaying neighborhood under gossip — ring/pairwise finish sooner
        and expose less comm on the dcn_transient profile."""
        prof = PROFILES["dcn_transient"]
        res = {}
        for topo in ("all", "ring", "pairwise"):
            cfg = SyncConfig(strategy="periodic", topology=topo,
                             overlap="delayed")
            res[topo] = simulate(prof, cfg, h=16, steps=4096, seed=0)
        assert res["ring"].wall_clock_s < res["all"].wall_clock_s
        assert res["pairwise"].wall_clock_s < res["all"].wall_clock_s
        assert res["ring"].comm_exposed_s < res["all"].comm_exposed_s

    def test_blocking_all_reduce_inherits_straggler_every_block(self):
        """One persistently 4× slower worker: every all-reduce barrier
        waits for it, so mean exposed wait per block ≈ its extra compute."""
        prof = PROFILES["dcn_straggler"]
        h = 8
        r = simulate(prof, SyncConfig(strategy="periodic"), h=h,
                     steps=1024, seed=0)
        extra = 3.0 * h * prof.workers[0].step_time   # (4−1)·H·t_step
        per_block_wait = r.comm_exposed_s / r.blocks
        assert per_block_wait == pytest.approx(
            extra * 7 / 8 + sync_wire_time_s(prof, CFG), rel=0.15)


class TestAdaptiveController:
    """Acceptance: controller within 20% of the simulator oracle on ≥ 2
    distinct profiles."""

    @pytest.mark.parametrize("name", ["dcn_default", "ici_pod"])
    def test_converges_within_20pct_of_oracle(self, name):
        prof = PROFILES[name]
        oh = oracle_h(prof, CFG, target_overhead=0.05, steps=2048, seed=0)
        ctrl = AdaptiveController(
            CFG, param_bytes_per_chip=prof.param_bytes,
            replicas=prof.world, link_bw=prof.link.bandwidth, h0=1,
            adapt_every=8, lr=1e-6)
        simulate_adaptive(prof, CFG, ctrl, blocks=200, seed=1)
        assert abs(ctrl.h - oh) <= 0.2 * oh, (ctrl.h, oh, ctrl.history)

    def test_straggler_profile_converges_exactly(self):
        """The host-observed calibration pair (slowest-shard compute +
        barrier-free collective) makes the persistent-straggler re-solve
        land on the oracle instead of chasing its own barrier wait."""
        prof = PROFILES["dcn_straggler"]
        oh = oracle_h(prof, CFG, target_overhead=0.05, steps=2048, seed=0)
        ctrl = AdaptiveController(
            CFG, param_bytes_per_chip=prof.param_bytes,
            replicas=prof.world, link_bw=prof.link.bandwidth, h0=1,
            adapt_every=8, lr=1e-6)
        simulate_adaptive(prof, CFG, ctrl, blocks=200, seed=1)
        assert abs(ctrl.h - oh) <= 0.2 * oh, (ctrl.h, oh)

    @settings(deadline=None, max_examples=10)
    @given(seed=st.integers(0, 1000))
    def test_convergence_property_over_seeds(self, seed):
        """Any measurement-noise realization lands within 20% of oracle on
        both graded profiles (the acceptance bar as a property)."""
        for prof in (PROFILES["dcn_default"], PROFILES["ici_pod"]):
            oh = oracle_h(prof, CFG, target_overhead=0.05, steps=2048,
                          seed=0)
            ctrl = AdaptiveController(
                CFG, param_bytes_per_chip=prof.param_bytes,
                replicas=prof.world, link_bw=prof.link.bandwidth, h0=1,
                adapt_every=8, lr=1e-6)
            simulate_adaptive(prof, CFG, ctrl, blocks=160, seed=seed)
            assert abs(ctrl.h - oh) <= 0.2 * oh, (prof.name, ctrl.h, oh)

    def test_history_records_transitions_and_h_bounded(self):
        prof = PROFILES["dcn_default"]
        ctrl = AdaptiveController(
            CFG, param_bytes_per_chip=prof.param_bytes,
            replicas=prof.world, link_bw=prof.link.bandwidth, h0=1,
            adapt_every=4, lr=1e-6, h_max=64)
        simulate_adaptive(prof, CFG, ctrl, blocks=64, seed=0)
        assert ctrl.history[0] == (0, 1)
        assert len(ctrl.history) >= 2          # it moved at least once
        assert all(1 <= h <= 64 for _, h in ctrl.history)


class TestChromeTrace:
    def test_trace_structure_and_monotone_slices(self):
        prof = PROFILES["dcn_straggler"]
        r = simulate(prof, SyncConfig(strategy="periodic", topology="ring",
                                      overlap="delayed"), h=4, blocks=8,
                     seed=0, record_timeline=True)
        doc = chrome_trace(r)
        assert "traceEvents" in doc
        evs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert evs, "no slices recorded"
        for e in evs:
            assert e["dur"] >= 0.0
            assert set(e) >= {"name", "ts", "dur", "pid", "tid", "cat"}
        # compute slices of one worker never overlap (its own timeline)
        per_worker = {}
        for s in r.timeline:
            if s.kind == "compute":
                per_worker.setdefault(s.worker, []).append((s.start, s.end))
        for spans in per_worker.values():
            spans.sort()
            for (s0, e0), (s1, _) in zip(spans, spans[1:]):
                assert s1 >= e0 - 1e-12
        # JSON-serializable end to end
        json.dumps(doc)

    def test_trace_has_stalls_only_under_delayed(self):
        prof = _quiet_dcn()
        blk = simulate(prof, SyncConfig(strategy="periodic"), h=4,
                       blocks=6, seed=0, record_timeline=True)
        kinds = {s.kind for s in blk.timeline}
        assert kinds == {"compute", "sync"}


class TestOracle:
    def test_oracle_meets_its_own_budget(self):
        prof = PROFILES["dcn_default"]
        oh = oracle_h(prof, CFG, target_overhead=0.05, steps=2048, seed=0)
        floor = simulate(prof, CFG, h=1024, steps=2048, seed=0).per_step_s
        at = simulate(prof, CFG, h=oh, steps=2048, seed=0).per_step_s
        assert at <= 1.05 * floor * (1 + 1e-6)
        if oh > 1:
            below = simulate(prof, CFG, h=oh - 1, steps=2048,
                             seed=0).per_step_s
            assert below > 1.05 * floor

    def test_oracle_smaller_on_faster_fabric(self):
        """Same compute, 8× the bandwidth ⇒ the oracle H shrinks."""
        slow = dcn_profile(jitter=0.0, name="slow")
        fast = ici_profile(step_time=2e-3, jitter=0.0, name="fast")
        h_slow = oracle_h(slow, CFG, steps=1024, seed=0)
        h_fast = oracle_h(fast, CFG, steps=1024, seed=0)
        assert h_fast < h_slow
