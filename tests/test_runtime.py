"""Fault-tolerant step runner: failure/restart replay, stragglers, pipeline."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.config import (CheckpointConfig, DataConfig, FaultToleranceConfig,
                          ModelConfig)
from repro.data.pipeline import DataPipeline
from repro.runtime import StepRunner
from repro.runtime.ft import SimulatedFault


def _toy_step():
    """state = {'w': scalar, 'sum': running sum of batch means}."""
    def step(state, batch):
        m = jnp.mean(batch["tokens"].astype(jnp.float32))
        new = {"w": state["w"] * 0.9 + 0.1 * m, "sum": state["sum"] + m}
        return new, {"loss": m}
    return step


def _mk_pipeline(cfg_data, model_cfg):
    def make(start):
        return DataPipeline(cfg_data, model_cfg, start_step=start)
    return make


@pytest.fixture
def setup(tmp_path):
    data_cfg = DataConfig(seq_len=8, global_batch=2, seed=3)
    model_cfg = ModelConfig(vocab_size=97)
    ckpt = CheckpointManager(CheckpointConfig(directory=str(tmp_path),
                                              interval_steps=5))
    return data_cfg, model_cfg, ckpt


class TestStepRunner:
    def test_failure_replay_is_bitwise_identical(self, setup, tmp_path):
        data_cfg, model_cfg, _ = setup
        state0 = {"w": jnp.float32(0), "sum": jnp.float32(0)}

        # run A: no failures
        ckpt_a = CheckpointManager(CheckpointConfig(
            directory=str(tmp_path / "a")))
        r_a = StepRunner(_toy_step(), ckpt_a, FaultToleranceConfig(),
                         ckpt_interval=5,
                         make_pipeline=_mk_pipeline(data_cfg, model_cfg))
        sa, _ = r_a.run(dict(state0), 0, 20)

        # run B: injected failure at step 13 → restore from step-10 ckpt
        ckpt_b = CheckpointManager(CheckpointConfig(
            directory=str(tmp_path / "b")))
        r_b = StepRunner(_toy_step(), ckpt_b,
                         FaultToleranceConfig(inject_failure_at=13),
                         ckpt_interval=5,
                         make_pipeline=_mk_pipeline(data_cfg, model_cfg))
        sb, _ = r_b.run(dict(state0), 0, 20)

        assert r_b.restarts == 1
        np.testing.assert_array_equal(np.asarray(sa["w"]), np.asarray(sb["w"]))
        np.testing.assert_array_equal(np.asarray(sa["sum"]),
                                      np.asarray(sb["sum"]))

    def test_exhausted_restarts_raise(self, setup, tmp_path):
        data_cfg, model_cfg, ckpt = setup

        def always_fail(state, batch):
            raise SimulatedFault("boom")

        r = StepRunner(always_fail, ckpt,
                       FaultToleranceConfig(max_restarts=2),
                       ckpt_interval=5,
                       make_pipeline=_mk_pipeline(data_cfg, model_cfg))
        with pytest.raises(SimulatedFault):
            r.run({"w": jnp.float32(0), "sum": jnp.float32(0)}, 0, 10)
        assert r.restarts == 3

    def test_straggler_detection(self, setup):
        data_cfg, model_cfg, ckpt = setup
        r = StepRunner(_toy_step(), ckpt,
                       FaultToleranceConfig(step_deadline_sec=1e-9),
                       ckpt_interval=100,
                       make_pipeline=_mk_pipeline(data_cfg, model_cfg))
        r.run({"w": jnp.float32(0), "sum": jnp.float32(0)}, 0, 3)
        assert len(r.watchdog.events) == 3   # every step "straggles"


class TestPipeline:
    def test_deterministic_across_instances(self):
        cfg = DataConfig(seq_len=16, global_batch=4, seed=5)
        mc = ModelConfig(vocab_size=128)
        a = next(DataPipeline(cfg, mc))
        b = next(DataPipeline(cfg, mc))
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))

    def test_resume_from_cursor(self):
        cfg = DataConfig(seq_len=16, global_batch=4, seed=5)
        mc = ModelConfig(vocab_size=128)
        p = DataPipeline(cfg, mc)
        for _ in range(5):
            next(p)               # advance the cursor
        st = p.state()
        q = DataPipeline(cfg, mc, start_step=st["step"])
        nxt_p, nxt_q = next(p), next(q)
        np.testing.assert_array_equal(np.asarray(nxt_p["tokens"]),
                                      np.asarray(nxt_q["tokens"]))

    def test_targets_are_shifted_tokens(self):
        cfg = DataConfig(seq_len=16, global_batch=2, seed=0)
        mc = ModelConfig(vocab_size=128)
        b = next(DataPipeline(cfg, mc))
        np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                      np.asarray(b["targets"][:, :-1]))


class TestEndToEndTrainer:
    def test_train_cli_smoke(self):
        """The full launch/train.py driver on 4 host devices with periodic
        sync, checkpointing, and a mid-run injected fault."""
        from conftest import run_with_devices
        code = """
import sys
sys.argv = ["train", "--arch", "smollm-360m", "--smoke", "--steps", "8",
            "--set", "sync.strategy=periodic", "--set", "sync.period=2",
            "--set", "mesh.replica_axis=data",
            "--set", "checkpoint.directory=/tmp/repro_test_ckpt",
            "--set", "checkpoint.interval_steps=2",
            "--set", "fault.inject_failure_at=5"]
from repro.launch import train
train.main()
"""
        out = run_with_devices(code, n_devices=4)
        import json
        rec = json.loads(out.strip().splitlines()[-1])
        assert rec["restarts"] == 1
        assert rec["last_loss"] is not None
"""NOTE: the trainer smoke uses mesh (4,1) with replica_axis=data — the
periodic strategy on the data axis (no FSDP), the paper's exact DMS
topology."""
