"""Asynchronous (unsynchronized-round) gossip — ISSUE 4's contracts.

* The double-buffered exchange applies a doubly stochastic matrix to the
  *previous* boundary's snapshot: the replica mean stays invariant even
  with per-replica drift injected between syncs, and with zero drift the
  recurrence is exactly synchronous gossip one round behind
  (``w_t = M w_{t−1}``) — staleness delays mixing, it does not distort it.
* Flush stays exact: the bare replica mean is the consensus target (the
  in-flight buffer corrections sum to zero), and ``finalize_state``
  re-seeds ``sent``/``mixbuf`` so a resume starts with a zero correction.
* The jaxpr shows ``ppermute`` only — no global collective — and across
  two chained blocks no dot consumes a ppermute output (the exchange has a
  full block of slack before anything reads it).
* The simulator's async mode is deterministic per seed and *strictly
  decouples* transient stragglers: on ``dcn_transient`` the clean-block
  mean time stays at the straggler-free profile while the synchronized
  ring inherits its neighbors' straggles.
* ``choose_period`` caps async H by the staleness-aware effective
  spectral gap (half the synchronous gossip cap for the 1-round buffer).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SyncConfig, TrainConfig
from repro.config.base import replace
from repro.core import costmodel
from repro.core import svm
from repro.core import sync as S
from repro.core.autotune import TuneInputs, choose_period, drift_cap
from repro.simsync import PROFILES, ClusterSim, chrome_trace, simulate
from conftest import run_with_devices


# ---------------------------------------------------------------------------
# config surface
# ---------------------------------------------------------------------------

class TestValidation:
    def test_async_needs_gossip_topology(self):
        with pytest.raises(ValueError, match="topology"):
            S.validate(SyncConfig(gossip_async=True))

    @pytest.mark.parametrize("overlap", ["delayed", "chunked"])
    def test_async_rejects_overlap_modes(self, overlap):
        with pytest.raises(ValueError, match="staleness"):
            S.validate(SyncConfig(topology="ring", gossip_async=True,
                                  overlap=overlap))

    def test_async_label(self):
        cfg = SyncConfig(strategy="periodic", topology="ring",
                         gossip_async=True)
        assert ",async" in cfg.msf_label

    def test_dms_entry_rejects_bad_async_combos(self):
        w0 = jnp.zeros(4)
        x = np.zeros((8, 4), np.float32)
        y = np.ones(8, np.float32)
        with pytest.raises(ValueError):
            svm.dms(w0, x, y, workers=2, epochs=1, block_size=2,
                    gossip_async=True)                    # topology="all"
        with pytest.raises(ValueError):
            svm.dms(w0, x, y, workers=2, epochs=1, block_size=2,
                    topology="ring", overlap="delayed", gossip_async=True)

    def test_simulator_rejects_async_all(self):
        with pytest.raises(ValueError):
            ClusterSim(PROFILES["dcn_default"],
                       SyncConfig(strategy="periodic", gossip_async=True))

    def test_async_state_has_double_buffers(self):
        cfg = SyncConfig(strategy="periodic", topology="ring",
                         gossip_async=True)
        p = {"w": jnp.ones((4,))}
        st = S.init_sync_state(cfg, p)
        assert set(st) == {"sent", "mixbuf"}
        # seeded so the first boundary's stale correction is exactly zero
        w_self = S.gossip_self_weight("ring")
        corr = (st["mixbuf"]["w"] + (w_self - 1.0) * st["sent"]["w"])
        np.testing.assert_allclose(np.asarray(corr), 0.0, atol=1e-7)
        axes = S.sync_state_axes(cfg, ("d",))
        assert set(axes) == {"sent", "mixbuf"}


# ---------------------------------------------------------------------------
# exchange semantics (real ppermutes, subprocess mesh)
# ---------------------------------------------------------------------------

class TestAsyncSemantics:
    def test_mean_invariant_stale_recurrence_and_compression(self):
        """(i) replica mean invariant under injected drift; (ii) zero
        drift ⇒ w_t = M w_{t−1} exactly (stale mixing delays, never
        distorts); (iii) compressed async wires still reach the mean."""
        code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import sync as S
from repro.core import costmodel
from repro.config import SyncConfig
k, d, rounds = 8, 16, 10
mesh = jax.make_mesh((k,), ("pod",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
vals = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
drift = jnp.asarray(rng.normal(size=(k, rounds, d)) * 0.1, jnp.float32)

def run(cfg, v, dr):
    n = dr.shape[1]
    def body(v, dr):
        p = {"w": v[0]}
        st = S.init_sync_state(cfg, p)
        outs = []
        for r in range(n):
            p = {"w": p["w"] + dr[0, r]}
            p, st = S.sync_point(p, p, st, cfg, "pod")
            outs.append(p["w"])
        return jnp.stack(outs)[None]
    f = jax.shard_map(body, mesh=mesh, in_specs=(P("pod"), P("pod")),
                      out_specs=P("pod"), axis_names={"pod"},
                      check_vma=False)
    with jax.set_mesh(mesh):
        return np.asarray(jax.jit(f)(v, dr))

for topo in ("ring", "pairwise"):
    cfg = SyncConfig(strategy="periodic", topology=topo, gossip_async=True)
    # (i) mean invariance with drift
    out = run(cfg, vals, drift)
    base = np.asarray(vals)
    dnp = np.asarray(drift)
    for r in range(rounds):
        want = (base + dnp[:, : r + 1].sum(axis=1)).mean(0)
        np.testing.assert_allclose(out[:, r].mean(0), want, rtol=2e-5,
                                   atol=2e-5, err_msg=f"{topo} r={r}")
    # (ii) zero drift: async == synchronous gossip one round behind
    out0 = run(cfg, vals, jnp.zeros_like(drift))
    mats = [np.asarray(m) for m in costmodel.mixing_matrices(k, topo)]
    want = base.copy()
    for r in range(rounds):
        np.testing.assert_allclose(out0[:, r], want, rtol=1e-4, atol=1e-5,
                                   err_msg=f"{topo} r={r}")
        # boundary r's exchange uses pairing parity r; its matrix lands
        # on the params one boundary later
        want = mats[r % len(mats)] @ want
    # (iii) compression: zero drift converges to the invariant mean.
    # Ring at K=8 mixes slowly (lam2 ~ 0.80 per round), so give the
    # contraction enough rounds that the quantization floor dominates.
    for comp, tol in (("int16", 1e-3), ("int8", 3e-2)):
        ccfg = SyncConfig(strategy="periodic", topology=topo,
                          gossip_async=True, compression=comp)
        outc = run(ccfg, vals, jnp.zeros((k, 56, d), jnp.float32))
        err = np.abs(outc[:, -1] - base.mean(0)).max()
        assert err < tol, (topo, comp, err)
print("OK")
"""
        assert "OK" in run_with_devices(code, n_devices=8)

    def test_vmap_matches_shard_map_and_timed_steps(self):
        """Static-matrix simulation ≡ real double-buffered ppermutes, and
        the timed sync path reproduces the same two-boundary recurrence."""
        code = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import svm
from repro.core import costmodel
from repro.launch.mesh import make_test_mesh
rng = np.random.default_rng(0)
x = rng.normal(size=(256, 12)).astype(np.float32)
y = np.where(rng.random(256) > 0.5, 1.0, -1.0).astype(np.float32)
w0 = jnp.zeros(12)
mesh = make_test_mesh((8,), ("data",))
for topo in ("ring", "pairwise"):
    wv = svm.dms(w0, x, y, workers=8, epochs=3, block_size=4,
                 topology=topo, gossip_async=True)
    with jax.set_mesh(mesh):
        ws = svm.dms(w0, x, y, workers=8, epochs=3, block_size=4,
                     backend="shard_map", mesh=mesh, topology=topo,
                     gossip_async=True)
    np.testing.assert_allclose(np.asarray(wv), np.asarray(ws), rtol=1e-5,
                               atol=1e-6, err_msg=topo)

# timed path: with zero drift, boundary 1 applies nothing (seed buffers),
# boundary 2 applies M @ w — the double buffer observed on the wire
k, d = 8, 32
wk = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
with jax.set_mesh(mesh):
    for topo in ("ring", "pairwise"):
        _, sync = svm.dms_timed_steps(mesh, "data", block_size=4,
                                      topology=topo, gossip_async=True)
        sent, mixbuf = svm.dms_async_buffers_init(wk, topo)
        w1, s1, b1 = sync(wk, sent, mixbuf, jnp.zeros((), jnp.int32))
        w2, s2, b2 = sync(w1, s1, b1, jnp.ones((), jnp.int32))
        M0 = np.asarray(costmodel.mixing_matrices(k, topo)[0])
        np.testing.assert_allclose(np.asarray(w1), np.asarray(wk),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(w2), M0 @ np.asarray(wk),
                                   rtol=1e-4, atol=1e-5, err_msg=topo)
print("OK")
"""
        assert "OK" in run_with_devices(code, n_devices=8)

    def test_lm_local_sgd_block_and_finalize(self):
        """The LM trainer path: async ring block runs, loss is finite, and
        finalize_state collapses the replicas to one consistent model with
        re-seeded double buffers (zero correction on resume)."""
        code = """
import jax, jax.numpy as jnp, numpy as np
from repro.config import (MeshConfig, OptimizerConfig, SyncConfig,
                          TrainConfig, DataConfig, get_smoke)
from repro.core import local_sgd as LS
from repro.core import sync as S
from repro.models.registry import build_model
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
mesh_cfg = MeshConfig(shape=(2, 2, 2), axis_names=("pod", "data", "model"),
                      replica_axis="pod")
cfg = TrainConfig(
    model=get_smoke("smollm-360m"), mesh=mesh_cfg,
    sync=SyncConfig(strategy="hierarchical", period=2, topology="ring",
                    gossip_async=True),
    optimizer=OptimizerConfig(name="sgd", learning_rate=0.05),
    data=DataConfig(seq_len=16, global_batch=8))
model = build_model(cfg.model)
with jax.set_mesh(mesh):
    state = LS.init_state(model, cfg, jax.random.key(0), replicas=2)
    step = LS.make_local_sgd_block(model, cfg, mesh)
    rng = np.random.default_rng(0)
    b = {"tokens": jnp.asarray(rng.integers(0, 512, (2, 8, 16)), jnp.int32),
         "targets": jnp.asarray(rng.integers(0, 512, (2, 8, 16)), jnp.int32)}
    for _ in range(3):
        state, metrics = jax.jit(step)(state, b)
    assert np.isfinite(float(metrics["loss"]))
    final = LS.finalize_state(state, cfg)
p = jax.device_get(final["params"])
for leaf in jax.tree.leaves(p):
    np.testing.assert_array_equal(leaf[0], leaf[1])
# buffers re-seeded from the flushed model: zero correction on resume
w_self = S.gossip_self_weight("ring")
sent = jax.device_get(final["sync"]["sent"])
mix = jax.device_get(final["sync"]["mixbuf"])
for pl, sl, ml in zip(jax.tree.leaves(p), jax.tree.leaves(sent),
                      jax.tree.leaves(mix)):
    np.testing.assert_allclose(sl, np.float32(pl), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(ml + (w_self - 1.0) * sl, 0.0, atol=1e-5)
print("OK")
"""
        assert "OK" in run_with_devices(code, n_devices=8)

    def test_async_ring_converges_on_ijcnn(self, ijcnn_small):
        ds = ijcnn_small
        for topo in ("ring", "pairwise"):
            w = svm.dms(jnp.zeros(ds.features), ds.x_train, ds.y_train,
                        workers=8, epochs=20, block_size=16, topology=topo,
                        gossip_async=True)
            acc = float(svm.accuracy(w, jnp.asarray(ds.x_cv),
                                     jnp.asarray(ds.y_cv)))
            assert acc > 0.75, (topo, acc)


# ---------------------------------------------------------------------------
# flush exactness (stacked layout, no mesh needed)
# ---------------------------------------------------------------------------

class TestFlushExactness:
    def _stacked_state(self, cfg, k=6, d=8, seed=0):
        rng = np.random.default_rng(seed)
        params = {"w": jnp.asarray(rng.normal(size=(k, d)), jnp.float32)}
        return {"params": params,
                "opt": {},
                "sync": S.init_sync_state(cfg, params),
                "step": jnp.zeros((), jnp.int32)}

    def test_flush_is_replica_mean(self):
        cfg = SyncConfig(strategy="periodic", topology="ring",
                         gossip_async=True)
        state = self._stacked_state(cfg)
        flushed = S.flush_overlap(state["params"], state["sync"], cfg)
        want = np.asarray(state["params"]["w"]).mean(0)
        got = np.asarray(flushed["w"])
        for r in range(got.shape[0]):
            np.testing.assert_allclose(got[r], want, rtol=1e-6, atol=1e-6)

    def test_finalize_reseeds_buffers(self):
        cfg = TrainConfig(sync=SyncConfig(strategy="periodic",
                                          topology="pairwise",
                                          gossip_async=True))
        from repro.core import local_sgd as LS
        state = self._stacked_state(cfg.sync)
        final = LS.finalize_state(state, cfg)
        p = np.asarray(final["params"]["w"])
        w_self = S.gossip_self_weight("pairwise")
        np.testing.assert_allclose(np.asarray(final["sync"]["sent"]["w"]),
                                   p, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(final["sync"]["mixbuf"]["w"]),
            (1.0 - w_self) * p, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# the schedule property, mechanically: jaxpr primitive analysis
# ---------------------------------------------------------------------------

def _async_block_jaxpr(topology: str, k: int = 8, d: int = 8):
    blockfn = svm._make_worker_block("pod", c=1.0, grad_impl="jnp",
                                     overlap="none", chunks=2, d=d,
                                     topology=topology, gossip_async=True)
    w_self = S.gossip_self_weight(topology)
    carry = {"w": jnp.zeros(d), "sent": jnp.zeros(d),
             "mixbuf": jnp.full(d, 1.0 - w_self)}
    if topology == "pairwise":
        carry["cnt"] = jnp.zeros((), jnp.int32)
    xb, yb = jnp.zeros((4, d)), jnp.zeros((4,))
    return carry, xb, yb, blockfn


class TestAsyncScheduleProperty:
    @pytest.mark.parametrize("topology", ["ring", "pairwise"])
    def test_async_block_is_ppermute_only(self, topology):
        from test_gossip import GLOBAL_COLLECTIVES, _collect_prims
        carry, xb, yb, blockfn = _async_block_jaxpr(topology)
        jaxpr = jax.make_jaxpr(
            lambda c, x, y: blockfn(c, x, y, 0.5),
            axis_env=[("pod", 8)])(carry, xb, yb).jaxpr
        prims = _collect_prims(jaxpr)
        assert "ppermute" in prims, prims
        bad = {p for p in prims
               if any(p.startswith(g) for g in GLOBAL_COLLECTIVES)}
        assert not bad, bad

    @pytest.mark.parametrize("topology", ["ring", "pairwise"])
    def test_async_ppermute_feeds_no_dot_across_two_blocks(self, topology):
        """Stronger than the delayed-overlap property: the exchange output
        lands only in the carried double buffers, so across two chained
        blocks no dot_general consumes any ppermute output — the wire has
        an entire block of slack before anything reads it."""
        from test_overlap import _collective_taints_dot
        carry, xb, yb, blockfn = _async_block_jaxpr(topology)

        def two_blocks(carry, x1, y1, x2, y2):
            c1 = blockfn(carry, x1, y1, 0.5)
            return blockfn(c1, x2, y2, 0.5)

        jaxpr = jax.make_jaxpr(two_blocks, axis_env=[("pod", 8)])(
            carry, xb, yb, xb, yb).jaxpr
        assert not _collective_taints_dot(jaxpr, source_prim="ppermute")

    def test_engine_sync_point_is_ppermute_only(self):
        """Same property for the generic engine path (LM trainer)."""
        from test_gossip import GLOBAL_COLLECTIVES, _collect_prims
        cfg = SyncConfig(strategy="periodic", topology="ring",
                         gossip_async=True)
        p = {"w": jnp.zeros(8)}
        st = S.init_sync_state(cfg, p)
        jaxpr = jax.make_jaxpr(
            lambda p, st: S.sync_point(p, p, st, cfg, "pod"),
            axis_env=[("pod", 8)])(p, st).jaxpr
        prims = _collect_prims(jaxpr)
        assert "ppermute" in prims, prims
        bad = {p for p in prims
               if any(p.startswith(g) for g in GLOBAL_COLLECTIVES)}
        assert not bad, bad


# ---------------------------------------------------------------------------
# simulator: deterministic, stall-free, strictly decoupled
# ---------------------------------------------------------------------------

ASYNC_CFG = SyncConfig(strategy="periodic", topology="ring",
                       gossip_async=True)


class TestSimulatorAsync:
    def test_deterministic_per_seed(self):
        a = simulate(PROFILES["dcn_transient"], ASYNC_CFG, h=8, steps=512,
                     seed=3)
        b = simulate(PROFILES["dcn_transient"], ASYNC_CFG, h=8, steps=512,
                     seed=3)
        assert a.wall_clock_s == b.wall_clock_s
        assert a.clean_block_mean_s == b.clean_block_mean_s
        assert a.stale_rounds_mean == b.stale_rounds_mean

    def test_async_never_stalls(self):
        r = simulate(PROFILES["dcn_transient"], ASYNC_CFG, h=16, steps=2048,
                     seed=0)
        assert r.comm_exposed_s == 0.0
        assert r.comm_wire_s > 0.0          # the wire is still occupied

    def test_strict_transient_straggler_decoupling(self):
        """Acceptance: each mode vs its OWN straggler-free run (so the
        ratio isolates straggle leakage, not scheduling overhead) — async
        clean blocks stay within 5% of straggler-free while the
        synchronized ring's clean blocks inherit neighbor straggles."""
        sync_cfg = SyncConfig(strategy="periodic", topology="ring",
                              overlap="delayed")
        ratios = {}
        for label, cfg in (("async", ASYNC_CFG), ("sync", sync_cfg)):
            base = simulate(PROFILES["dcn_default"], cfg, h=16, steps=4096,
                            seed=0)
            r = simulate(PROFILES["dcn_transient"], cfg, h=16, steps=4096,
                         seed=0)
            ratios[label] = (r.clean_block_mean_s / base.clean_block_mean_s,
                             r)
        assert ratios["async"][0] <= 1.05, ratios["async"][0]
        assert ratios["sync"][0] > 1.2, ratios["sync"][0]
        assert (ratios["async"][1].wall_clock_s
                < ratios["sync"][1].wall_clock_s)

    def test_staleness_is_one_round_without_stragglers(self):
        """Uniform workers and t_comm ≪ block ⇒ the consumed buffer is
        the neighbor's previous round — the nominal double-buffer bound."""
        r = simulate(PROFILES["dcn_default"], ASYNC_CFG, h=16, steps=2048,
                     seed=0)
        assert 0.9 <= r.stale_rounds_mean <= 1.1, r.stale_rounds_mean
        assert r.stale_rounds_max <= 2, r.stale_rounds_max

    def test_straggle_shifts_round_staleness_not_clean_blocks(self):
        r = simulate(PROFILES["dcn_transient"], ASYNC_CFG, h=16, steps=4096,
                     seed=0)
        # a 20x transient pushes the straggler ~19 blocks behind in rounds;
        # staleness grows while everyone else keeps computing
        assert r.stale_rounds_max > 2
        assert r.straggled_frac > 0.0

    def test_async_trace_has_no_stall_lanes(self):
        r = simulate(PROFILES["dcn_transient"], ASYNC_CFG, h=16, blocks=16,
                     seed=0, record_timeline=True)
        kinds = {s.kind for s in r.timeline}
        assert kinds == {"compute", "sync"}, kinds
        doc = chrome_trace(r)
        assert doc["traceEvents"], "empty trace"
        sync_ring = simulate(
            PROFILES["dcn_transient"],
            SyncConfig(strategy="periodic", topology="ring",
                       overlap="delayed"), h=16, blocks=16, seed=0,
            record_timeline=True)
        assert any(s.kind == "stall" for s in sync_ring.timeline)

    def test_pairwise_async_runs(self):
        cfg = replace(ASYNC_CFG, topology="pairwise")
        r = simulate(PROFILES["dcn_transient"], cfg, h=8, steps=512, seed=1)
        assert r.comm_exposed_s == 0.0
        assert r.blocks == 64


# ---------------------------------------------------------------------------
# tuner: staleness-aware spectral-gap cap
# ---------------------------------------------------------------------------

class TestStalenessCap:
    def _inp(self, k=8):
        # huge comm pressure so h_comm is large and the drift cap binds
        return TuneInputs(param_bytes_per_chip=10**12, replicas=k,
                          step_time_s=1e-4, link_bw=6.25e9,
                          grad_norm=1.0, param_norm=100.0, lr=1e-3)

    def test_effective_gap_reduces_to_gap_and_halves(self):
        for k in (4, 8, 16):
            for topo in ("ring", "pairwise"):
                gap = costmodel.spectral_gap(k, topo)
                assert costmodel.effective_spectral_gap(
                    k, topo, staleness=0) == gap
                assert costmodel.effective_spectral_gap(
                    k, topo, staleness=1) == pytest.approx(gap / 2)
        with pytest.raises(ValueError):
            costmodel.effective_spectral_gap(8, "ring", staleness=-1)

    def test_choose_period_halves_async_cap(self):
        inp = self._inp()
        cap = drift_cap(inp, 0.01)
        for topo in ("ring", "pairwise"):
            h_sync = choose_period(
                inp, SyncConfig(strategy="periodic", topology=topo),
                max_drift=0.01)
            h_async = choose_period(
                inp, SyncConfig(strategy="periodic", topology=topo,
                                gossip_async=True), max_drift=0.01)
            gap = costmodel.spectral_gap(8, topo)
            assert h_async == max(1, int(cap * gap / 2)), (topo, h_async)
            assert h_async <= h_sync

    def test_async_step_time_model_is_overlapped(self):
        cfg = SyncConfig(strategy="periodic", topology="ring",
                         gossip_async=True)
        # collective fits under the block ⇒ per-step time is compute-bound
        assert costmodel.overlapped_step_time(1e-3, 4e-3, 8, cfg) == \
            pytest.approx(1e-3)
        # and is exposed only when it outlasts the block
        assert costmodel.overlapped_step_time(1e-3, 16e-3, 8, cfg) == \
            pytest.approx(2e-3)
