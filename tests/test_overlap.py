"""Overlap-aware sync engine: delayed/chunked semantics, cost model, bytes.

Covers the tentpole's contracts:

* ``overlap="none"`` preserves the paper's DMS ≡ SRDMS identity bit-exact.
* ``overlap="delayed"`` equals an independently-written stale-by-one
  reference simulation in fp64.
* ``overlap="chunked"`` syncs each segment/leaf exactly once per R blocks.
* The delayed block's sync collective is not a dependency of any compute
  (dot) in the same or the following block — verifiable from the jaxpr.
* ``collective_bytes_per_sync`` and the autotuner's ``sync_time_s`` agree
  for every (compression × overlap) combination (shared cost module).
* ``choose_period(overlap="delayed")`` never picks a larger H than blocking.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SyncConfig
from repro.core import svm
from repro.core import sync as S
from repro.core.autotune import TuneInputs, choose_period, predicted_step_time, sync_time_s
from repro.core.costmodel import overlapped_step_time, wire_bytes_per_sync
from conftest import run_with_devices


def _toy(n=256, d=10, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = np.where(rng.random(n) > 0.5, 1.0, -1.0).astype(np.float32)
    return x, y


# ---------------------------------------------------------------------------
# semantics
# ---------------------------------------------------------------------------

class TestOverlapNone:
    def test_none_is_bitexact_default(self):
        """overlap="none" is the same compiled path as the paper default."""
        x, y = _toy()
        w0 = jnp.zeros(10)
        wa = svm.dms(w0, x, y, workers=4, epochs=2, block_size=4)
        wb = svm.dms(w0, x, y, workers=4, epochs=2, block_size=4,
                     overlap="none")
        np.testing.assert_array_equal(np.asarray(wa), np.asarray(wb))

    def test_none_keeps_dms_srdms_identity(self):
        """DMS(K, s_b) ≡ SRDMS(K·s_b) still holds with overlap="none"."""
        from test_svm_core import _interleave
        k, sb = 4, 2
        x, y = _toy()
        x, y, xi, yi = _interleave(x, y, k, sb)
        w0 = jnp.zeros(10)
        wd = svm.dms(w0, x, y, workers=k, epochs=2, block_size=sb,
                     overlap="none")
        wr = svm.srdms(w0, jnp.asarray(xi), jnp.asarray(yi), epochs=2,
                       block_size=k * sb)
        np.testing.assert_allclose(np.asarray(wd), np.asarray(wr),
                                   rtol=1e-5, atol=1e-6)


class TestDelayed:
    def test_delayed_equals_stale_reference_fp64(self):
        """dms(overlap="delayed") == an independent numpy stale-by-one
        simulation, in fp64 (per-worker models carry anchor + own last Δ;
        the mean of block i lands at the end of block i+1)."""
        code = """
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp, numpy as np
from repro.core import svm

rng = np.random.default_rng(3)
k, n, d, bs, epochs, c = 4, 128, 6, 4, 3, 1.0
x = rng.normal(size=(n, d))
y = np.where(rng.random(n) > 0.5, 1.0, -1.0)
w0 = jnp.zeros(d, jnp.float64)

w_jax = np.asarray(svm.dms(w0, x, y, workers=k, epochs=epochs,
                           block_size=bs, overlap="delayed"))

# ---- independent stale-by-one reference ----
n_local = n // k
xs = x[: n_local * k].reshape(k, n_local, d)
ys = y[: n_local * k].reshape(k, n_local)
wk = np.zeros((k, d))
pending = np.zeros((k, d))
for t in range(epochs):
    alpha = 1.0 / (1.0 + t)
    for b in range(n_local // bs):
        deltas = np.zeros((k, d))
        for kk in range(k):
            xb = xs[kk, b * bs:(b + 1) * bs]
            yb = ys[kk, b * bs:(b + 1) * bs]
            margins = 1.0 - yb * (xb @ wk[kk])
            viol = (margins > 0).astype(np.float64)
            g = wk[kk] - c * ((viol * yb) @ xb) / bs
            deltas[kk] = -alpha * g
        mean = deltas.mean(0)
        wk = wk + deltas + pending        # apply own Δ + stale correction
        pending = mean[None] - deltas     # next block's correction
w_ref = wk.mean(0)                        # flush: anchor + meanΔ_last

err = np.abs(w_jax - w_ref).max()
print("ERR", err)
assert err < 1e-12, err
"""
        out = run_with_devices(code, n_devices=1)
        assert float(out.strip().split()[-1]) < 1e-12

    def test_delayed_shard_map_matches_vmap(self):
        code = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import svm
from repro.launch.mesh import make_test_mesh
rng = np.random.default_rng(0)
x = rng.normal(size=(256, 12)).astype(np.float32)
y = np.where(rng.random(256) > 0.5, 1.0, -1.0).astype(np.float32)
w0 = jnp.zeros(12)
mesh = make_test_mesh((8,), ("data",))
for ov in ("delayed", "chunked"):
    wv = svm.dms(w0, x, y, workers=8, epochs=3, block_size=4, overlap=ov)
    with jax.set_mesh(mesh):
        ws = svm.dms(w0, x, y, workers=8, epochs=3, block_size=4,
                     backend="shard_map", mesh=mesh, overlap=ov)
    np.testing.assert_allclose(np.asarray(wv), np.asarray(ws),
                               rtol=1e-5, atol=1e-6)
print("OK")
"""
        assert "OK" in run_with_devices(code)

    def test_delayed_converges(self, ijcnn_small):
        ds = ijcnn_small
        w = svm.dms(jnp.zeros(ds.features), ds.x_train, ds.y_train,
                    workers=8, epochs=20, block_size=16, overlap="delayed")
        acc = float(svm.accuracy(w, jnp.asarray(ds.x_cv),
                                 jnp.asarray(ds.y_cv)))
        assert acc > 0.75, acc


class TestChunked:
    def test_chunked_syncs_each_segment_once_per_round(self):
        """With alpha=0 (no drift) and divergent worker models, segment i
        becomes the worker mean exactly at block i — one full round of R
        blocks makes every coordinate consistent, and never before."""
        code = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import svm
from repro.launch.mesh import make_test_mesh
k, d, chunks, bs = 4, 10, 3, 2
mesh = make_test_mesh((k,), ("data",))
rng = np.random.default_rng(0)
w_init = rng.normal(size=(k, d)).astype(np.float32)
with jax.set_mesh(mesh):
    step = svm.dms_block_stepper(mesh, "data", d=d, overlap="chunked",
                                 chunks=chunks)
    carry = svm.dms_stepper_init(jnp.zeros(d), k, overlap="chunked",
                                 chunks=chunks)
    dp = carry["w"].shape[1]
    seg = dp // chunks
    carry["w"] = jnp.zeros((k, dp)).at[:, :d].set(w_init)
    xb = jnp.zeros((k, bs, d), jnp.float32)
    yb = jnp.zeros((k, bs), jnp.float32)
    wp = np.zeros((k, dp), np.float32)
    wp[:, :d] = w_init
    mean = wp.mean(0)
    for i in range(chunks):
        carry = jax.jit(step)(carry, xb, yb, jnp.float32(0.0))
        w = np.asarray(carry["w"])
        # segments 0..i synced to the mean, the rest untouched
        for s in range(chunks):
            lo, hi = s * seg, (s + 1) * seg
            if s <= i:
                np.testing.assert_allclose(
                    w[:, lo:hi], np.broadcast_to(mean[lo:hi], (k, hi - lo)),
                    rtol=1e-6, atol=1e-7)
            else:
                np.testing.assert_array_equal(w[:, lo:hi], wp[:, lo:hi])
    assert int(carry["cnt"]) == chunks
print("OK")
"""
        assert "OK" in run_with_devices(code, n_devices=4)

    def test_chunked_tree_round_robin(self):
        """sync_point(overlap="chunked") on a 3-leaf tree, R=3: exactly the
        leaves of shard (idx % R) are replaced by their replica mean."""
        code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import sync as S
from repro.config import SyncConfig
n_rep = 4
cfg = SyncConfig(strategy="periodic", overlap="chunked", chunks=3)
mesh = jax.make_mesh((n_rep,), ("pod",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
vals = jnp.asarray(rng.normal(size=(n_rep, 3, 5)), jnp.float32)

def body(vals):
    v = vals[0]
    params = {"a": v[0], "b": v[1], "c": v[2]}
    st = S.init_sync_state(cfg, params)
    outs = []
    for _ in range(3):
        params, st = S.sync_point(params, params, st, cfg, "pod")
        outs.append(jnp.stack([params["a"], params["b"], params["c"]]))
    return jnp.stack(outs)[None]

f = jax.shard_map(body, mesh=mesh, in_specs=(P("pod"),),
                  out_specs=P("pod"), axis_names={"pod"}, check_vma=False)
with jax.set_mesh(mesh):
    out = np.asarray(jax.jit(f)(vals))     # (n_rep, 3 calls, 3 leaves, 5)
base = np.asarray(vals)
mean = base.mean(0)
for call in range(3):
    for leaf in range(3):
        got = out[:, call, leaf]
        if leaf <= call:     # leaf i syncs at call i (shard id = leaf idx)
            np.testing.assert_allclose(
                got, np.broadcast_to(mean[leaf], got.shape),
                rtol=1e-6, atol=1e-7)
        else:
            np.testing.assert_array_equal(got, base[:, leaf])
print("OK")
"""
        assert "OK" in run_with_devices(code, n_devices=4)

    def test_chunked_converges(self, ijcnn_small):
        ds = ijcnn_small
        w = svm.dms(jnp.zeros(ds.features), ds.x_train, ds.y_train,
                    workers=8, epochs=20, block_size=16, overlap="chunked",
                    chunks=4)
        acc = float(svm.accuracy(w, jnp.asarray(ds.x_cv),
                                 jnp.asarray(ds.y_cv)))
        assert acc > 0.75, acc

    def test_slowmo_chunked_accepted_with_anchor_state(self):
        """ROADMAP item lifted: chunked × slowmo composes via a per-shard
        outer momentum — the state carries the momentum buffer plus the
        per-leaf anchor (value after the leaf's own last slowmo step)."""
        cfg = SyncConfig(overlap="chunked", slowmo=0.5)
        st = S.init_sync_state(cfg, {"w": jnp.ones(4)})
        assert set(st) == {"chunk_idx", "slowmo_m", "anchor"}
        np.testing.assert_array_equal(np.asarray(st["anchor"]["w"]),
                                      np.ones(4, np.float32))
        # logical-axes tree mirrors the state (checkpoint/sharding path)
        axes = S.sync_state_axes(cfg, {"w": ("x",)})
        assert set(axes) == set(st)
        # gossip topologies still reject slowmo (no global mean exists)
        with pytest.raises(ValueError):
            S.validate(SyncConfig(overlap="chunked", slowmo=0.5,
                                  topology="ring"))

    def test_slowmo_chunks1_equals_blocking_slowmo(self):
        """chunks=1 degenerates to a whole-tree value sync every boundary:
        anchor ≡ block start and mean(w_end) − anchor ≡ meanΔ, so the
        per-shard momentum step must reproduce the blocking slowmo path
        exactly — the identity anchoring the per-shard generalization."""
        code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import sync as S
from repro.config import SyncConfig

k, d, nb = 4, 8, 4
mesh = jax.make_mesh((k,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
start = rng.normal(size=(d,)).astype(np.float32)
upds = jnp.asarray(rng.normal(size=(nb, k, d)).astype(np.float32))

def run(cfg):
    def body(start, upds):
        p = {"w": start}
        st = S.init_sync_state(cfg, p)
        for t in range(nb):
            p_end = {"w": p["w"] + upds[t, 0]}
            p, st = S.sync_point(p, p_end, st, cfg, "pod")
        return p["w"][None]
    f = jax.shard_map(body, mesh=mesh, in_specs=(P(), P(None, "pod")),
                      out_specs=P("pod"), axis_names={"pod"}, check_vma=False)
    with jax.set_mesh(mesh):
        return np.asarray(jax.jit(f)(jnp.asarray(start), upds))

blocking = run(SyncConfig(strategy="periodic", slowmo=0.7, slowmo_lr=0.9))
chunked1 = run(SyncConfig(strategy="periodic", slowmo=0.7, slowmo_lr=0.9,
                          overlap="chunked", chunks=1))
err = np.abs(blocking - chunked1).max()
print("ERR", err)
assert err < 1e-5, err
"""
        out = run_with_devices(code, n_devices=4)
        assert float(out.strip().split()[-1]) < 1e-5

    def test_slowmo_chunked_multishard_momentum_accumulates(self):
        """With zero drift and divergent replicas, each leaf's first sync
        pulls it toward the replica mean by slowmo_lr (momentum has one
        term); a second visit with β > 0 moves it further — per-shard
        momentum really accumulates per leaf, on the leaf's own sync
        cadence."""
        code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import sync as S
from repro.config import SyncConfig

k = 4
beta, lr_out = 0.5, 1.0
cfg = SyncConfig(strategy="periodic", overlap="chunked", chunks=2,
                 slowmo=beta, slowmo_lr=lr_out)
mesh = jax.make_mesh((k,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))
ends = jnp.asarray(np.arange(k, dtype=np.float32))   # replica r holds value r

def body(ends):
    p = {"a": jnp.full((3,), ends[0]), "b": jnp.full((3,), -ends[0])}
    st = S.init_sync_state(cfg, p)
    outs = []
    for t in range(4):
        # zero drift: params_end == params (anchor stays where slowmo put it)
        p, st = S.sync_point(p, p, st, cfg, "pod")
        outs.append(jnp.stack([p["a"][0], p["b"][0]]))
    return jnp.stack(outs)[None]

f = jax.shard_map(body, mesh=mesh, in_specs=(P("pod"),),
                  out_specs=P("pod"), axis_names={"pod"}, check_vma=False)
with jax.set_mesh(mesh):
    out = np.asarray(jax.jit(f)(ends))
mean_a = np.mean(np.arange(k))          # 1.5
# replica 0, leaf "a" (shard 0, synced at boundaries 0 and 2):
# boundary 0: m = mean - 0 = 1.5 -> a = 1.5; boundary 2: all replicas at
# the mean already, delta = 0, m = beta*1.5 -> a = 1.5 + beta*1.5
np.testing.assert_allclose(out[0, 0, 0], mean_a, rtol=1e-6)
np.testing.assert_allclose(out[0, 2, 0], mean_a * (1 + beta), rtol=1e-6)
# leaf "b" unsynced at boundary 0 (shard 1 syncs at boundary 1)
np.testing.assert_allclose(out[0, 0, 1], 0.0, atol=1e-7)
np.testing.assert_allclose(out[0, 1, 1], -mean_a, rtol=1e-6)
print("OK")
"""
        assert "OK" in run_with_devices(code, n_devices=4)

    def test_chunk_assignment_balances_bytes(self):
        """Shards are byte-balanced: a skewed tree must not put the huge
        leaf plus extras on one shard while another idles."""
        leaves = [jnp.zeros((100,)), jnp.zeros((1,)), jnp.zeros((1,)),
                  jnp.zeros((1,))]
        assign = S.chunk_assignment(leaves, 2)
        big_shard = assign[0]
        assert all(a != big_shard for a in assign[1:]), assign
        # equal-size leaves fall back to round-robin (ties by leaf order)
        assign_eq = S.chunk_assignment([jnp.zeros(5)] * 3, 3)
        assert sorted(assign_eq) == [0, 1, 2], assign_eq

    def test_chunk_assignment_weighs_dtype_bytes(self):
        """Mixed-precision regression: balancing by element count would
        pair the bf16 leaf with an extra on one shard while the
        same-element-count fp32 leaf idles alone — by *bytes* the fp32
        leaf (2× wire weight) must sit alone and the two bf16 leaves
        together."""
        f32 = jnp.zeros((64,), jnp.float32)      # 256 bytes
        b16a = jnp.zeros((64,), jnp.bfloat16)    # 128 bytes
        b16b = jnp.zeros((64,), jnp.bfloat16)    # 128 bytes
        assign = S.chunk_assignment([f32, b16a, b16b], 2)
        assert assign[1] == assign[2] != assign[0], assign
        # element-count ties with different itemsize are NOT ties in bytes:
        # greedy largest-first places the fp32 leaf before either bf16 one
        assign2 = S.chunk_assignment([b16a, f32, b16b], 2)
        assert assign2[0] == assign2[2] != assign2[1], assign2


class TestFlush:
    def test_flush_overlap_recovers_synchronized_model(self):
        """Delayed replicas sit at anchor + ownΔ with
        pending = stepΔ − ownΔ; flush must return anchor + stepΔ on every
        replica — exact even when stepΔ carries a slowmo momentum term that
        a bare replica mean would drop."""
        rng = np.random.default_rng(0)
        anchor = rng.normal(size=(6,)).astype(np.float32)
        deltas = rng.normal(size=(4, 6)).astype(np.float32)
        # stepΔ ≠ meanΔ (simulates slowmo momentum folded into the step)
        step_delta = deltas.mean(0) + 0.9 * rng.normal(size=6).astype(np.float32)
        stacked = {"w": jnp.asarray(anchor[None] + deltas)}
        sync_state = {"pending": {"w": jnp.asarray(step_delta[None] - deltas)}}
        cfg = SyncConfig(strategy="periodic", overlap="delayed")
        flushed = S.flush_overlap(stacked, sync_state, cfg)
        want = anchor + step_delta
        np.testing.assert_allclose(
            np.asarray(flushed["w"]), np.broadcast_to(want, (4, 6)),
            rtol=1e-5, atol=1e-5)
        # overlap="none" passes through untouched (replicas already equal)
        same = S.flush_overlap(stacked, {}, SyncConfig(strategy="periodic"))
        np.testing.assert_array_equal(np.asarray(same["w"]),
                                      np.asarray(stacked["w"]))

    def test_flush_overlap_folds_error_feedback_residual(self):
        """Compression regression: the EF buffer is quantization error each
        replica would have re-submitted at its next sync — dropping it on
        flush biases a checkpoint-resume. Flush must add the per-replica
        residual before the collapse (its replica mean survives), and
        finalize_state must zero the buffer so resume doesn't double-count."""
        rng = np.random.default_rng(1)
        anchor = rng.normal(size=(6,)).astype(np.float32)
        deltas = rng.normal(size=(4, 6)).astype(np.float32)
        efs = 0.01 * rng.normal(size=(4, 6)).astype(np.float32)
        step_delta = deltas.mean(0)
        stacked = {"w": jnp.asarray(anchor[None] + deltas)}
        sync_state = {"pending": {"w": jnp.asarray(step_delta[None] - deltas)},
                      "ef": {"w": jnp.asarray(efs)}}
        cfg = SyncConfig(strategy="periodic", overlap="delayed",
                         compression="int8")
        flushed = S.flush_overlap(stacked, sync_state, cfg)
        want = anchor + step_delta + efs.mean(0)
        np.testing.assert_allclose(
            np.asarray(flushed["w"]), np.broadcast_to(want, (4, 6)),
            rtol=1e-5, atol=1e-5)

    def test_finalize_state_zeroes_folded_ef(self):
        from repro.config import TrainConfig
        from repro.core import local_sgd as LS
        cfg = TrainConfig(sync=SyncConfig(strategy="periodic",
                                          overlap="delayed",
                                          compression="int8"))
        state = {"params": {"w": jnp.ones((2, 4))},
                 "opt": {}, "step": jnp.zeros((), jnp.int32),
                 "sync": {"pending": {"w": jnp.zeros((2, 4))},
                          "ef": {"w": jnp.full((2, 4), 0.25)}}}
        out = LS.finalize_state(state, cfg)
        # residual folded into params…
        np.testing.assert_allclose(np.asarray(out["params"]["w"]), 1.25,
                                   rtol=1e-6)
        # …and cleared from the state (no double count on resume)
        assert float(np.abs(np.asarray(out["sync"]["ef"]["w"])).max()) == 0.0

    def test_finalize_state_clears_pending(self):
        from repro.config import TrainConfig
        from repro.core import local_sgd as LS
        cfg = TrainConfig(sync=SyncConfig(strategy="periodic",
                                          overlap="delayed"))
        state = {"params": {"w": jnp.arange(8, dtype=jnp.float32
                                            ).reshape(2, 4)},
                 "opt": {}, "step": jnp.zeros((), jnp.int32),
                 "sync": {"pending": {"w": jnp.ones((2, 4))}}}
        out = LS.finalize_state(state, cfg)
        leaf = np.asarray(out["params"]["w"])
        np.testing.assert_array_equal(leaf[0], leaf[1])  # replicas equal
        assert float(np.abs(np.asarray(out["sync"]["pending"]["w"])).max()) == 0.0


class TestLocalSGDOverlap:
    def test_lm_block_runs_and_finalizes(self):
        """The LM trainer path: delayed/chunked thread through sync_point,
        eval_at_sync evaluates the *synced* model, and finalize_state
        collapses the replicas to one consistent model."""
        code = """
import jax, jax.numpy as jnp, numpy as np
from repro.config import (MeshConfig, OptimizerConfig, SyncConfig,
                          TrainConfig, DataConfig, get_smoke)
from repro.core import local_sgd as LS
from repro.models.registry import build_model
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
mesh_cfg = MeshConfig(shape=(2, 2, 2), axis_names=("pod", "data", "model"),
                      replica_axis="pod")
for ov in ("delayed", "chunked"):
    cfg = TrainConfig(
        model=get_smoke("smollm-360m"), mesh=mesh_cfg,
        sync=SyncConfig(strategy="hierarchical", period=2, overlap=ov,
                        chunks=3, eval_at_sync=True),
        optimizer=OptimizerConfig(name="sgd", learning_rate=0.05),
        data=DataConfig(seq_len=16, global_batch=8))
    model = build_model(cfg.model)
    with jax.set_mesh(mesh):
        state = LS.init_state(model, cfg, jax.random.key(0), replicas=2)
        step = LS.make_local_sgd_block(model, cfg, mesh)
        rng = np.random.default_rng(0)
        b = {"tokens": jnp.asarray(rng.integers(0, 512, (2, 8, 16)),
                                   jnp.int32),
             "targets": jnp.asarray(rng.integers(0, 512, (2, 8, 16)),
                                    jnp.int32)}
        for _ in range(3):
            state, metrics = jax.jit(step)(state, b)
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["sync_eval_loss"]))
        state = LS.finalize_state(state, cfg)
        for leaf in jax.tree.leaves(jax.device_get(state["params"])):
            np.testing.assert_array_equal(leaf[0], leaf[1])
print("OK")
"""
        assert "OK" in run_with_devices(code, n_devices=8)


# ---------------------------------------------------------------------------
# the overlap property, mechanically: jaxpr dependency analysis
# ---------------------------------------------------------------------------

try:
    from jax.extend.core import Literal as _Literal
except ImportError:      # older jax
    from jax.core import Literal as _Literal


def _collective_taints_dot(jaxpr, source_prim: str = "psum") -> bool:
    """True iff any dot_general transitively consumes a ``source_prim``
    output (prefix match — also used by test_gossip with "ppermute")."""
    tainted = set()
    found = False
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        in_tainted = any(getattr(v, "count", None) is not None and v in tainted
                         for v in eqn.invars
                         if not isinstance(v, _Literal))
        if prim == "dot_general" and in_tainted:
            found = True
        if prim.startswith(source_prim) or in_tainted:
            tainted.update(v for v in eqn.outvars)
    return found


def _two_block_jaxpr(overlap: str, chunks: int = 2):
    d, bs, k_axis = 8, 4, 4
    blockfn = svm._make_worker_block("pod", c=1.0, grad_impl="jnp",
                                     overlap=overlap, chunks=chunks, d=d)
    dp = -(-d // chunks) * chunks if overlap == "chunked" else d
    carry = {"w": jnp.zeros(dp)}
    if overlap == "delayed":
        carry["pending"] = jnp.zeros(d)
    if overlap == "chunked":
        carry["cnt"] = jnp.zeros((), jnp.int32)
    xb = jnp.zeros((bs, d))
    yb = jnp.zeros((bs,))

    def two_blocks(carry, x1, y1, x2, y2):
        c1 = blockfn(carry, x1, y1, 0.5)
        return blockfn(c1, x2, y2, 0.5)

    return jax.make_jaxpr(two_blocks, axis_env=[("pod", k_axis)])(
        carry, xb, yb, xb, yb).jaxpr


class TestOverlapDependencyStructure:
    def test_blocking_collective_feeds_next_block_compute(self):
        """Sanity: with blocking sync, block 2's dots DO consume block 1's
        pmean — the collective is on the critical path."""
        assert _collective_taints_dot(_two_block_jaxpr("none"))

    def test_delayed_collective_feeds_no_compute(self):
        """The overlap property: across two chained delayed blocks, no dot
        depends on any sync collective — the pmean result only flows into
        the pending correction (pure adds), so XLA can schedule the
        collective concurrently with the next block's compute."""
        assert not _collective_taints_dot(_two_block_jaxpr("delayed"))


# ---------------------------------------------------------------------------
# cost model + byte accounting
# ---------------------------------------------------------------------------

def _inp(step=0.09, p=int(235e9 * 4 / 256), k=2, bw=6.25e9):
    return TuneInputs(param_bytes_per_chip=p, replicas=k, step_time_s=step,
                      link_bw=bw, grad_norm=1.0, param_norm=100.0, lr=3e-4)


class TestByteAccountingUnified:
    @pytest.mark.parametrize("compression", ["none", "int8", "int16"])
    @pytest.mark.parametrize("overlap", ["none", "delayed", "chunked"])
    def test_sync_bytes_and_tuner_agree(self, compression, overlap):
        """collective_bytes_per_sync and sync_time_s·BW must agree for every
        (compression × overlap) combination — both read costmodel."""
        cfg = SyncConfig(strategy="periodic", period=8,
                         compression=compression, overlap=overlap, chunks=4)
        for k in (2, 4, 16):
            p = 10_000_000
            inp = _inp(p=p, k=k, bw=1e9)
            from_tuner = sync_time_s(inp, cfg) * inp.link_bw
            from_sync = S.collective_bytes_per_sync(p, k, cfg)
            assert from_sync == pytest.approx(from_tuner, rel=1e-9, abs=1.0)
            assert from_sync == pytest.approx(
                wire_bytes_per_sync(p, k, cfg), rel=1e-9, abs=1.0)

    def test_chunked_divides_wire_bytes(self):
        p, k = 8_000_000, 4
        base = S.collective_bytes_per_sync(p, k, SyncConfig())
        quarter = S.collective_bytes_per_sync(
            p, k, SyncConfig(overlap="chunked", chunks=4))
        assert quarter == pytest.approx(base / 4, rel=1e-6)

    def test_delayed_same_wire_bytes(self):
        p, k = 8_000_000, 4
        assert (S.collective_bytes_per_sync(p, k, SyncConfig()) ==
                S.collective_bytes_per_sync(
                    p, k, SyncConfig(overlap="delayed")))


class TestOverlapCostModel:
    def test_delayed_step_time_is_max_form(self):
        cfg = SyncConfig(overlap="delayed")
        inp = _inp()
        t_sync = sync_time_s(inp, cfg)
        for h in (1, 4, 64, 1024):
            assert predicted_step_time(inp, cfg, h) == pytest.approx(
                max(inp.step_time_s, t_sync / h))

    def test_overlapped_step_time_never_worse(self):
        inp = _inp()
        for h in (1, 2, 8, 64, 512):
            t_block = predicted_step_time(inp, SyncConfig(), h)
            t_delay = predicted_step_time(
                inp, SyncConfig(overlap="delayed"), h)
            t_chunk = predicted_step_time(
                inp, SyncConfig(overlap="chunked", chunks=4), h)
            assert t_delay <= t_block
            assert t_chunk <= t_block

    def test_choose_period_delayed_le_blocking(self):
        """Acceptance: delayed H ≤ blocking H for the same TuneInputs."""
        for k in (2, 4):
            for target in (0.01, 0.05, 0.2):
                inp = _inp(k=k)
                hb = choose_period(inp, target_overhead=target, max_drift=1.0)
                hd = choose_period(inp, target_overhead=target, max_drift=1.0,
                                   overlap="delayed")
                assert hd <= hb, (hd, hb, target)
                assert hd >= 1

    def test_choose_period_delayed_meets_exposed_target(self):
        inp = _inp()
        cfg = SyncConfig(strategy="hierarchical", overlap="delayed")
        h = choose_period(inp, cfg, target_overhead=0.05, max_drift=1.0)
        exposed = max(0.0, sync_time_s(inp, cfg) / h - inp.step_time_s)
        assert exposed / inp.step_time_s <= 0.05 + 1e-9
        if h > 1:
            exposed_prev = max(0.0,
                               sync_time_s(inp, cfg) / (h - 1) - inp.step_time_s)
            assert exposed_prev / inp.step_time_s > 0.05

    def test_chunked_drift_cap_scales_with_chunks(self):
        """Each leaf averages every chunks·H steps, so the drift cap must
        bind H at drift_cap/chunks — not the raw blocking cap."""
        from repro.core.autotune import drift_cap
        inp = TuneInputs(param_bytes_per_chip=10**12, replicas=2,
                         step_time_s=1e-4, link_bw=6.25e9,
                         grad_norm=1.0, param_norm=100.0, lr=1e-3)
        cap = drift_cap(inp, 0.01)
        cfg = SyncConfig(overlap="chunked", chunks=4)
        h = choose_period(inp, cfg, target_overhead=0.05, max_drift=0.01)
        assert cap > 4  # comm pressure is huge, so the cap binds
        assert h == max(1, cap // 4), (h, cap)

    def test_report_overhead_consistent_with_step_time(self):
        from repro.core.autotune import report
        inp = _inp()
        rep = report(inp, SyncConfig(strategy="hierarchical",
                                     overlap="delayed"))
        for h, row in rep["ladder"].items():
            want = (row["step_s"] - inp.step_time_s) / inp.step_time_s
            assert row["overhead"] == pytest.approx(want)
            assert row["overhead"] >= 0.0

    def test_overlapped_step_time_matches_costmodel(self):
        cfg = SyncConfig(overlap="delayed")
        inp = _inp()
        assert predicted_step_time(inp, cfg, 16) == overlapped_step_time(
            inp.step_time_s, sync_time_s(inp, cfg), 16, cfg)
