"""Checkpoint manager + elastic reshaping."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, rescale_replicated_state
from repro.checkpoint.elastic import add_replica_dim, drop_replica_dim
from repro.config import CheckpointConfig


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(8,)), jnp.float32)},
        "opt": {"mu": {"w": jnp.zeros((4, 8)), "b": jnp.zeros(8)}},
        "step": jnp.int32(7),
    }


class TestManager:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path)))
        s = _state()
        mgr.save(7, s, extra={"data": {"step": 7}})
        like = jax.tree.map(jnp.zeros_like, s)
        restored, extra = mgr.restore(like)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), s, restored)
        assert extra == {"data": {"step": 7}}

    def test_latest_and_keep_last(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path),
                                                 keep_last=2))
        for step in (1, 2, 3, 4):
            mgr.save(step, _state(step))
        assert mgr.latest_step() == 4
        assert mgr.all_steps() == [3, 4]

    def test_async_write(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path),
                                                 async_write=True))
        s = _state()
        mgr.save(1, s)
        mgr.wait()
        restored, _ = mgr.restore(jax.tree.map(jnp.zeros_like, s))
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(s["params"]["w"]))

    def test_fingerprint_mismatch_raises(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path)))
        s = _state()
        mgr.save(1, s, fingerprint="abc")
        with pytest.raises(ValueError, match="fingerprint"):
            mgr.restore(s, expected_fingerprint="def")

    def test_no_tmp_dirs_left(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path)))
        mgr.save(1, _state())
        assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]

    def test_missing_checkpoint_raises(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path)))
        with pytest.raises(FileNotFoundError):
            mgr.restore(_state())


class TestElastic:
    def test_shrink_averages(self):
        s = {"w": jnp.stack([jnp.ones(4), 3 * jnp.ones(4)])}
        out = rescale_replicated_state(s, 2, 1)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   2 * np.ones((1, 4)))

    def test_grow_broadcasts_average(self):
        s = {"w": jnp.stack([jnp.ones(4), 3 * jnp.ones(4)])}
        out = rescale_replicated_state(s, 2, 4)
        assert out["w"].shape == (4, 4)
        np.testing.assert_allclose(np.asarray(out["w"]), 2.0)

    def test_add_then_drop_is_identity(self):
        s = {"w": jnp.arange(6.0).reshape(2, 3)}
        up = add_replica_dim(s, 4)
        assert up["w"].shape == (4, 2, 3)
        down = drop_replica_dim(up)
        np.testing.assert_allclose(np.asarray(down["w"]), np.asarray(s["w"]))

    def test_scalar_leaves_pass_through(self):
        s = {"step": jnp.int32(5), "w": jnp.ones((2, 3))}
        out = rescale_replicated_state(s, 2, 3)
        assert int(out["step"]) == 5
        assert out["w"].shape == (3, 3)
