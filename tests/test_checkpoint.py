"""Checkpoint manager + elastic reshaping + sync-state round-trips."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_with_devices
from repro.checkpoint import CheckpointManager, rescale_replicated_state
from repro.checkpoint.elastic import add_replica_dim, drop_replica_dim
from repro.config import CheckpointConfig


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(8,)), jnp.float32)},
        "opt": {"mu": {"w": jnp.zeros((4, 8)), "b": jnp.zeros(8)}},
        "step": jnp.int32(7),
    }


class TestManager:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path)))
        s = _state()
        mgr.save(7, s, extra={"data": {"step": 7}})
        like = jax.tree.map(jnp.zeros_like, s)
        restored, extra = mgr.restore(like)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), s, restored)
        assert extra == {"data": {"step": 7}}

    def test_latest_and_keep_last(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path),
                                                 keep_last=2))
        for step in (1, 2, 3, 4):
            mgr.save(step, _state(step))
        assert mgr.latest_step() == 4
        assert mgr.all_steps() == [3, 4]

    def test_async_write(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path),
                                                 async_write=True))
        s = _state()
        mgr.save(1, s)
        mgr.wait()
        restored, _ = mgr.restore(jax.tree.map(jnp.zeros_like, s))
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(s["params"]["w"]))

    def test_fingerprint_mismatch_raises(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path)))
        s = _state()
        mgr.save(1, s, fingerprint="abc")
        with pytest.raises(ValueError, match="fingerprint"):
            mgr.restore(s, expected_fingerprint="def")

    def test_no_tmp_dirs_left(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path)))
        mgr.save(1, _state())
        assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]

    def test_missing_checkpoint_raises(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path)))
        with pytest.raises(FileNotFoundError):
            mgr.restore(_state())


class TestElastic:
    def test_shrink_averages(self):
        s = {"w": jnp.stack([jnp.ones(4), 3 * jnp.ones(4)])}
        out = rescale_replicated_state(s, 2, 1)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   2 * np.ones((1, 4)))

    def test_grow_broadcasts_average(self):
        s = {"w": jnp.stack([jnp.ones(4), 3 * jnp.ones(4)])}
        out = rescale_replicated_state(s, 2, 4)
        assert out["w"].shape == (4, 4)
        np.testing.assert_allclose(np.asarray(out["w"]), 2.0)

    def test_add_then_drop_is_identity(self):
        s = {"w": jnp.arange(6.0).reshape(2, 3)}
        up = add_replica_dim(s, 4)
        assert up["w"].shape == (4, 2, 3)
        down = drop_replica_dim(up)
        np.testing.assert_allclose(np.asarray(down["w"]), np.asarray(s["w"]))

    def test_scalar_leaves_pass_through(self):
        s = {"step": jnp.int32(5), "w": jnp.ones((2, 3))}
        out = rescale_replicated_state(s, 2, 3)
        assert int(out["step"]) == 5
        assert out["w"].shape == (3, 3)


class TestSyncStateRoundTrip:
    """ISSUE 3 satellite: checkpointing MID-STREAM — with live overlap
    state (pending correction, error-feedback residual, slowmo momentum,
    chunk/gossip counters all nonzero, replicas divergent, NOT finalized)
    — then restoring and continuing must be bit-identical to the
    uninterrupted run, across overlap × compression × slowmo × gossip."""

    def test_mid_stream_resume_bitexact(self):
        code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.checkpoint import CheckpointManager
from repro.config import CheckpointConfig, SyncConfig
from repro.core import sync as S
import tempfile

k, d, nb = 4, 16, 5
mesh = jax.make_mesh((k,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
w0 = rng.normal(size=(d,)).astype(np.float32)
# per-replica drift per boundary — distinct across replicas so pending /
# EF / momentum are all nonzero at the checkpoint
upds = jnp.asarray(rng.normal(size=(nb, k, d)).astype(np.float32))

def make_step(cfg):
    def body(p, st, u):
        lp = {"w": p["w"][0]}
        lst = jax.tree.map(lambda x: x[0], st)
        end = {"w": lp["w"] + u[0]}
        np_, nst = S.sync_point(lp, end, lst, cfg, "pod")
        re = lambda t: jax.tree.map(lambda x: x[None], t)
        return re(np_), re(nst)
    f = jax.shard_map(body, mesh=mesh,
                      in_specs=(P("pod"), P("pod"), P("pod")),
                      out_specs=(P("pod"), P("pod")),
                      axis_names={"pod"}, check_vma=False)
    return jax.jit(f)

cfgs = [
    # delayed overlap + int8 EF + slowmo momentum (global collective)
    SyncConfig(strategy="periodic", overlap="delayed", compression="int8",
               slowmo=0.6, slowmo_lr=0.9),
    # delayed overlap + int16 EF over ring gossip
    SyncConfig(strategy="periodic", overlap="delayed", compression="int16",
               topology="ring"),
    # chunked overlap + per-shard slowmo (anchor + momentum state)
    SyncConfig(strategy="periodic", overlap="chunked", chunks=2,
               slowmo=0.5),
    # chunked overlap + int8 EF over pairwise gossip (chunk_idx parity)
    SyncConfig(strategy="periodic", overlap="chunked", chunks=2,
               compression="int8", topology="pairwise"),
]
with jax.set_mesh(mesh):
    for cfg in cfgs:
        step = make_step(cfg)
        bcast = lambda x: jnp.broadcast_to(x, (k,) + x.shape)
        p = {"w": bcast(jnp.asarray(w0))}
        st = jax.tree.map(bcast, S.init_sync_state(cfg, {"w": jnp.asarray(w0)}))
        # run 2 boundaries, checkpoint mid-stream, run 3 more
        for t in range(2):
            p, st = step(p, st, upds[t])
        with tempfile.TemporaryDirectory() as tmp:
            mgr = CheckpointManager(CheckpointConfig(directory=tmp))
            mgr.save(2, {"params": p, "sync": st})
            pa, sa = p, st
            for t in range(2, nb):
                pa, sa = step(pa, sa, upds[t])
            like = jax.tree.map(jnp.zeros_like, {"params": p, "sync": st})
            restored, _ = mgr.restore(like)
        pb = jax.tree.map(jnp.asarray, restored["params"])
        sb = jax.tree.map(jnp.asarray, restored["sync"])
        for t in range(2, nb):
            pb, sb = step(pb, sb, upds[t])
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), pa, pb)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), sa, sb)
        # sanity: the checkpointed state really was mid-stream (live
        # overlap buffers, not a finalized/flushed one)
        live = jax.tree.map(np.asarray, jax.device_get(st))
        if "pending" in live:
            assert np.abs(live["pending"]["w"]).max() > 0
        if "ef" in live:
            assert np.abs(live["ef"]["w"]).max() > 0
        if "slowmo_m" in live:
            assert np.abs(live["slowmo_m"]["w"]).max() > 0
        if "chunk_idx" in live:
            assert int(live["chunk_idx"][0]) == 2
print("OK")
"""
        assert "OK" in run_with_devices(code, n_devices=4)
