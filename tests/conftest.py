"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the real
single CPU device; multi-device tests spawn subprocesses that set
``--xla_force_host_platform_device_count`` themselves."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a python snippet in a subprocess with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def ijcnn_small():
    from repro.data import make_svm_dataset
    return make_svm_dataset("ijcnn1", seed=0, n_override=4000)
