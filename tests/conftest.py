"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the real
single CPU device; multi-device tests spawn subprocesses that set
``--xla_force_host_platform_device_count`` themselves."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


# ---------------------------------------------------------------------------
# optional-hypothesis shim. ``hypothesis`` is a dev-only dependency
# (requirements-dev.txt); when it is absent the property-based tests must
# *skip*, not kill collection. Test modules import via
# ``try: from hypothesis import ... except ImportError: from conftest import ...``
# and get these stand-ins: ``given`` marks the test skipped, ``settings`` is
# a pass-through, ``st`` yields inert strategy placeholders.
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
    # CI runs the property tests across a 2/4/8-device matrix on shared
    # runners: a load-spike deadline kill or a randomized example order
    # must not flake a leg. The pinned profile derandomizes example
    # generation (same examples every run — regressions reproduce locally
    # by construction) and disables the wall-clock deadline. Activated
    # only under CI (GitHub Actions sets CI=true); local runs keep
    # hypothesis' exploratory defaults.
    settings.register_profile("ci", deadline=None, derandomize=True,
                              print_blob=True)
    if os.environ.get("CI"):
        settings.load_profile("ci")
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed "
                       "(pip install -r requirements-dev.txt)")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        def __getattr__(self, name):
            def strategy(*_args, **_kwargs):
                return None
            return strategy

    st = _StrategyStub()


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a python snippet in a subprocess with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def ijcnn_small():
    from repro.data import make_svm_dataset
    return make_svm_dataset("ijcnn1", seed=0, n_override=4000)
