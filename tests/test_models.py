"""Per-arch smoke tests + prefill/decode consistency for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_smoke, list_archs
from repro.models.registry import analytic_param_count, build_model

ARCHS = list_archs()


def _extras(cfg, b):
    ex = {}
    if cfg.family == "vlm":
        ex["patches"] = jnp.asarray(
            np.random.default_rng(1).normal(
                size=(b, cfg.num_image_tokens, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "audio":
        ex["frames"] = jnp.asarray(
            np.random.default_rng(1).normal(
                size=(b, cfg.n_audio_frames, cfg.d_model)), jnp.bfloat16)
    return ex


@pytest.mark.parametrize("arch", ARCHS)
class TestSmoke:
    def test_train_step_shapes_and_finite(self, arch):
        cfg = get_smoke(arch)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        b, s = 2, 32
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
            "targets": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
            **_extras(cfg, b),
        }
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        assert jnp.isfinite(loss), (arch, loss)
        leaves = jax.tree.leaves(grads)
        assert all(jnp.all(jnp.isfinite(g)) for g in leaves), arch
        assert any(float(jnp.max(jnp.abs(g))) > 0 for g in leaves), \
            f"{arch}: all-zero gradients"

    def test_one_sgd_step_reduces_loss(self, arch):
        from repro.config import OptimizerConfig
        from repro.optim import apply_updates, init_opt_state
        cfg = get_smoke(arch)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        b, s = 2, 16
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
            "targets": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
            **_extras(cfg, b),
        }
        ocfg = OptimizerConfig(name="sgd", learning_rate=0.1)
        opt = init_opt_state(ocfg, params)
        (l0, _), g = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        params, opt = apply_updates(ocfg, g, opt, params, jnp.int32(0))
        l1, _ = model.loss(params, batch)
        assert float(l1) < float(l0), (arch, float(l0), float(l1))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """decode_step after an s−1 prefill must reproduce the s-long prefill's
    next-token logits — the cache/index bookkeeping proof, per family."""
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b, s = 2, 12
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)), jnp.int32)
    extras = _extras(cfg, b)

    full_logits, _ = model.prefill(params, {"tokens": tokens, **extras})

    pre_logits, cache = model.prefill(
        params, {"tokens": tokens[:, :s - 1], **extras})
    # grow seq-dim cache buffers to hold the next token (the VLM cache
    # also covers the image prefix)
    max_len = s + 4 + (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    full_cache = model.init_cache(b, max_len)
    def grow(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        pad = [(0, d - c) for d, c in zip(dst.shape, src.shape)]
        return jnp.pad(src.astype(dst.dtype), pad)
    cache = jax.tree.map(grow, full_cache, cache)

    # decode position: image/audio prefixes shift the cache index
    index = s - 1
    if cfg.family == "vlm":
        index += cfg.num_image_tokens
    step_logits, _ = model.decode_step(
        params, {"token": tokens[:, s - 1:s], "cache": cache,
                 "index": jnp.int32(index)})
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32),
        np.asarray(full_logits, np.float32), rtol=5e-2, atol=5e-2)


def test_param_counts_match_published():
    from repro.config import get_arch
    expected = {
        "phi3.5-moe-42b-a6.6b": (41.9e9, 6.6e9),
        "qwen3-moe-235b-a22b": (235e9, 22.1e9),
        "llama3.2-3b": (3.6e9, 3.6e9),
        "internlm2-1.8b": (1.9e9, 1.9e9),
        "smollm-360m": (0.36e9, 0.36e9),
        "qwen2.5-3b": (3.4e9, 3.4e9),
        "whisper-base": (0.08e9, 0.08e9),
        "mamba2-2.7b": (2.8e9, 2.8e9),
        "zamba2-1.2b": (1.2e9, 1.2e9),
        "paligemma-3b": (2.5e9, 2.5e9),
    }
    for arch, (total, active) in expected.items():
        cfg = get_arch(arch)
        t = analytic_param_count(cfg)
        a = analytic_param_count(cfg, active_only=True)
        assert abs(t - total) / total < 0.1, (arch, t, total)
        assert abs(a - active) / active < 0.1, (arch, a, active)


def test_vlm_loss_ignores_image_positions():
    """Prefix-LM: corrupting image patches must change the loss, but the
    loss mask covers text targets only (text-target count normalizes)."""
    cfg = get_smoke("paligemma-3b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b, s = 2, 8
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)),
                               jnp.int32),
        **_extras(cfg, b),
    }
    l0, _ = model.loss(params, batch)
    assert jnp.isfinite(l0)


def test_whisper_cross_attention_sees_encoder():
    """Changing the audio frames must change the decoder loss."""
    cfg = get_smoke("whisper-base")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b, s = 2, 8
    rng = np.random.default_rng(0)
    base = {
        "tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)),
                               jnp.int32),
    }
    f1 = jnp.asarray(rng.normal(size=(b, cfg.n_audio_frames, cfg.d_model)),
                     jnp.bfloat16)
    f2 = jnp.asarray(rng.normal(size=(b, cfg.n_audio_frames, cfg.d_model)),
                     jnp.bfloat16)
    l1, _ = model.loss(params, dict(base, frames=f1))
    l2, _ = model.loss(params, dict(base, frames=f2))
    assert abs(float(l1) - float(l2)) > 1e-6
