"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

RNG = np.random.default_rng(42)


class TestHinge:
    @pytest.mark.parametrize("n,d", [(8, 8), (100, 22), (257, 254),
                                     (512, 2000), (64, 128), (33, 7)])
    @pytest.mark.parametrize("dtype", [jnp.float32])
    def test_matches_ref(self, n, d, dtype):
        from repro.kernels.hinge import ops, ref
        x = jnp.asarray(RNG.normal(size=(n, d)), dtype)
        y = jnp.asarray(np.where(RNG.random(n) > 0.5, 1.0, -1.0), dtype)
        w = jnp.asarray(RNG.normal(size=d), dtype)
        got = ops.hinge_block_grad(w, x, y, 1.0)
        want = ref.hinge_block_grad(w, x, y, 1.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)

    def test_c_scaling(self):
        from repro.kernels.hinge import ops, ref
        x = jnp.asarray(RNG.normal(size=(64, 16)), jnp.float32)
        y = jnp.asarray(np.where(RNG.random(64) > 0.5, 1.0, -1.0), jnp.float32)
        w = jnp.asarray(RNG.normal(size=16), jnp.float32)
        for c in (0.1, 1.0, 10.0):
            np.testing.assert_allclose(
                np.asarray(ops.hinge_block_grad(w, x, y, c)),
                np.asarray(ref.hinge_block_grad(w, x, y, c)),
                rtol=1e-4, atol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("b,sq,sk,h,kv,dh,causal,pref", [
        (1, 128, 128, 4, 2, 64, True, 0),
        (2, 256, 256, 8, 8, 128, True, 0),
        (1, 200, 200, 6, 2, 64, True, 0),        # unaligned seq
        (1, 128, 128, 4, 1, 64, True, 32),        # MQA + prefix-LM
        (2, 64, 300, 4, 4, 64, False, 0),         # cross attn, padded keys
        (1, 512, 512, 2, 2, 32, True, 0),         # dh below lane width
    ])
    def test_matches_ref(self, b, sq, sk, h, kv, dh, causal, pref):
        from repro.kernels.flash_attention import ops, ref
        q = jnp.asarray(RNG.normal(size=(b, sq, h, dh)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(b, sk, kv, dh)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(b, sk, kv, dh)), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=causal, prefix_len=pref)
        want = ref.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=causal,
                             prefix_len=pref).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=2e-5)

    def test_bf16(self):
        from repro.kernels.flash_attention import ops, ref
        q = jnp.asarray(RNG.normal(size=(1, 128, 4, 64)), jnp.bfloat16)
        k = jnp.asarray(RNG.normal(size=(1, 128, 2, 64)), jnp.bfloat16)
        v = jnp.asarray(RNG.normal(size=(1, 128, 2, 64)), jnp.bfloat16)
        got = ops.flash_attention(q, k, v, causal=True)
        want = ref.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3),
                             causal=True).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=5e-2, atol=5e-2)


class TestSSD:
    @pytest.mark.parametrize("b,l,h,p,n,chunk", [
        (1, 128, 2, 64, 128, 64),
        (2, 256, 4, 64, 128, 128),
        (1, 200, 2, 64, 64, 128),                 # unaligned L
        (1, 512, 1, 128, 128, 256),
        (2, 64, 3, 32, 16, 32),
    ])
    def test_matches_exact_recurrence(self, b, l, h, p, n, chunk):
        from repro.kernels.ssd import ops, ref
        x = jnp.asarray(RNG.normal(size=(b, l, h, p)), jnp.float32)
        dt = jnp.asarray(RNG.uniform(0.001, 0.1, size=(b, l, h)), jnp.float32)
        a = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(h,)), jnp.float32)
        bm = jnp.asarray(RNG.normal(size=(b, l, n)), jnp.float32)
        cm = jnp.asarray(RNG.normal(size=(b, l, n)), jnp.float32)
        ya, sa = ops.ssd_scan(x, dt, a, bm, cm, chunk=chunk)
        yb, sb = ref.ssd_scan(x, dt, a, bm, cm)
        np.testing.assert_allclose(np.asarray(ya), np.asarray(yb),
                                   rtol=1e-3, atol=2e-4)
        np.testing.assert_allclose(np.asarray(sa), np.asarray(sb),
                                   rtol=1e-3, atol=2e-4)

    def test_jnp_chunked_twin_matches(self):
        """models/ssm.ssd_chunked (the XLA path) vs kernel ref."""
        from repro.kernels.ssd import ref
        from repro.models.ssm import ssd_chunked
        b, l, h, p, n = 2, 96, 2, 16, 8
        x = jnp.asarray(RNG.normal(size=(b, l, h, p)), jnp.float32)
        dt = jnp.asarray(RNG.uniform(0.001, 0.1, size=(b, l, h)), jnp.float32)
        a = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(h,)), jnp.float32)
        bm = jnp.asarray(RNG.normal(size=(b, l, n)), jnp.float32)
        cm = jnp.asarray(RNG.normal(size=(b, l, n)), jnp.float32)
        ya, sa = ssd_chunked(x, dt, a, bm, cm, chunk=32)
        yb, sb = ref.ssd_scan(x, dt, a, bm, cm)
        np.testing.assert_allclose(np.asarray(ya), np.asarray(yb),
                                   rtol=1e-3, atol=2e-4)
        np.testing.assert_allclose(np.asarray(sa), np.asarray(sb),
                                   rtol=1e-3, atol=2e-4)


class TestQuant:
    @pytest.mark.parametrize("shape", [(100,), (33, 7), (2, 3, 5), (4096,),
                                       (128, 128)])
    def test_roundtrip_matches_ref(self, shape):
        from repro.kernels.quant import ops, ref
        x = jnp.asarray(RNG.normal(size=shape), jnp.float32)
        qa, sa = ops.quantize(x)
        qb, sb = ref.quantize(x)
        assert np.array_equal(np.asarray(qa), np.asarray(qb))
        np.testing.assert_allclose(float(sa), float(sb), rtol=1e-6)
        da = ops.dequantize(qa, sa)
        db = ref.dequantize(qb, sb)
        np.testing.assert_allclose(np.asarray(da), np.asarray(db), rtol=1e-6)

    def test_quantization_error_bound(self):
        from repro.kernels.quant import ops
        x = jnp.asarray(RNG.normal(size=(1000,)), jnp.float32)
        q, s = ops.quantize(x)
        err = np.abs(np.asarray(ops.dequantize(q, s)) - np.asarray(x))
        assert err.max() <= float(s) / 2 + 1e-6
