"""Paper algorithms 1–3: faithfulness, equivalence, convergence."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # optional dev dep: property tests skip
    from conftest import given, settings, st

from repro.core import svm


def _interleave(x, y, k, sb):
    """Reorder data so SRDMS(K·sb) sees the same block unions as
    DMS(K, sb) on contiguous worker shards — the paper's §IV-B setup."""
    n = (x.shape[0] // (k * sb)) * (k * sb)
    x, y = x[:n], y[:n]
    xs = x.reshape(k, n // k, -1)
    ys = y.reshape(k, n // k)
    nb = (n // k) // sb
    xi = np.concatenate([
        np.stack([xs[w, b * sb:(b + 1) * sb] for w in range(k)]
                 ).reshape(k * sb, -1) for b in range(nb)])
    yi = np.concatenate([
        np.stack([ys[w, b * sb:(b + 1) * sb] for w in range(k)]
                 ).reshape(k * sb) for b in range(nb)])
    return x, y, xi, yi


class TestPaperEquivalence:
    """DMS(K, s_b) ≡ SRDMS(K·s_b) — the paper's own validation method."""

    @settings(deadline=None, max_examples=12)
    @given(k=st.sampled_from([2, 4, 8]), sb=st.sampled_from([1, 2, 4, 8]),
           seed=st.integers(0, 5))
    def test_dms_equals_srdms(self, k, sb, seed):
        rng = np.random.default_rng(seed)
        n, d = 256, 10
        x = rng.normal(size=(n, d)).astype(np.float32)
        y = np.where(rng.random(n) > 0.5, 1.0, -1.0).astype(np.float32)
        x, y, xi, yi = _interleave(x, y, k, sb)
        w0 = jnp.zeros(d)
        wd = svm.dms(w0, x, y, workers=k, epochs=2, block_size=sb)
        wr = svm.srdms(w0, jnp.asarray(xi), jnp.asarray(yi), epochs=2,
                       block_size=k * sb)
        np.testing.assert_allclose(np.asarray(wd), np.asarray(wr),
                                   rtol=1e-5, atol=1e-6)

    def test_block1_equals_pointwise_average(self):
        """block_size=1 SRDMS reduces to plain SGD (paper: 'block size of
        unity resembles the standard algorithm')."""
        rng = np.random.default_rng(0)
        x = rng.normal(size=(64, 8)).astype(np.float32)
        y = np.where(rng.random(64) > 0.5, 1.0, -1.0).astype(np.float32)
        w0 = jnp.zeros(8)
        w_seq = svm.seq_sgd(w0, jnp.asarray(x), jnp.asarray(y), epochs=1)
        w_blk = svm.srdms(w0, jnp.asarray(x), jnp.asarray(y), epochs=1,
                          block_size=1)
        np.testing.assert_allclose(np.asarray(w_seq), np.asarray(w_blk),
                                   rtol=1e-5, atol=1e-6)


class TestHingeMath:
    @settings(deadline=None, max_examples=20)
    @given(seed=st.integers(0, 100))
    def test_block_grad_is_objective_subgradient(self, seed):
        """At differentiable points the block gradient matches autodiff of
        the (mean-normalized) objective."""
        rng = np.random.default_rng(seed)
        n, d, c = 32, 6, 1.0
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        y = jnp.asarray(np.where(rng.random(n) > 0.5, 1.0, -1.0), jnp.float32)
        w = jnp.asarray(rng.normal(size=d), jnp.float32)
        margins = 1.0 - y * (x @ w)
        if bool(jnp.any(jnp.abs(margins) < 1e-3)):
            return  # too close to the hinge kink
        obj = lambda w: 0.5 * jnp.dot(w, w) + c * jnp.mean(
            jnp.maximum(0.0, 1.0 - y * (x @ w)))
        auto = jax.grad(obj)(w)
        manual = svm.block_grad(w, x, y, c)
        np.testing.assert_allclose(np.asarray(manual), np.asarray(auto),
                                   rtol=1e-4, atol=1e-5)

    def test_objective_decreases(self, ijcnn_small):
        ds = ijcnn_small
        w0 = jnp.zeros(ds.features)
        x, y = jnp.asarray(ds.x_train), jnp.asarray(ds.y_train)
        j0 = svm.hinge_objective(w0, x, y)
        w = svm.srdms(w0, x, y, epochs=10, block_size=64)
        j1 = svm.hinge_objective(w, x, y)
        assert float(j1) < float(j0)


class TestConvergence:
    """Paper §V-A: accuracy is insensitive to block size (MSF)."""

    def test_accuracy_flat_across_block_sizes(self, ijcnn_small):
        ds = ijcnn_small
        x, y = jnp.asarray(ds.x_train), jnp.asarray(ds.y_train)
        xcv, ycv = jnp.asarray(ds.x_cv), jnp.asarray(ds.y_cv)
        w0 = jnp.zeros(ds.features)
        accs = {}
        for bs in [8, 64, 512]:
            w = svm.srdms(w0, x, y, epochs=20, block_size=bs)
            accs[bs] = float(svm.accuracy(w, xcv, ycv))
        assert min(accs.values()) > 0.75, accs
        # paper: ±1% across MSFs after convergence; allow 5% on the
        # smaller synthetic stand-in
        assert max(accs.values()) - min(accs.values()) < 0.05, accs

    def test_block1_converges_given_more_epochs(self, ijcnn_small):
        """block=1 (highest MSF) is noisy early — α=1/(1+t) starts at 1 —
        and needs more epochs on the small stand-in; the paper notes the
        same initialization sensitivity on Ijcnn1 (§V-A)."""
        ds = ijcnn_small
        w = svm.srdms(jnp.zeros(ds.features), jnp.asarray(ds.x_train),
                      jnp.asarray(ds.y_train), epochs=80, block_size=1)
        acc = float(svm.accuracy(w, jnp.asarray(ds.x_cv),
                                 jnp.asarray(ds.y_cv)))
        assert acc > 0.75, acc

    def test_dms_vmap_converges(self, ijcnn_small):
        ds = ijcnn_small
        w0 = jnp.zeros(ds.features)
        w = svm.dms(w0, ds.x_train, ds.y_train, workers=8, epochs=20,
                    block_size=16)
        acc = float(svm.accuracy(w, jnp.asarray(ds.x_cv),
                                 jnp.asarray(ds.y_cv)))
        assert acc > 0.75, acc

    def test_pallas_grad_impl_matches(self, ijcnn_small):
        ds = ijcnn_small
        x, y = jnp.asarray(ds.x_train[:512]), jnp.asarray(ds.y_train[:512])
        w0 = jnp.zeros(ds.features)
        w_jnp = svm.srdms(w0, x, y, epochs=2, block_size=64,
                          grad_impl="jnp")
        w_pal = svm.srdms(w0, x, y, epochs=2, block_size=64,
                          grad_impl="pallas")
        np.testing.assert_allclose(np.asarray(w_jnp), np.asarray(w_pal),
                                   rtol=1e-4, atol=1e-5)


class TestDistributedBackend:
    def test_shard_map_backend_matches_vmap(self, run=None):
        from conftest import run_with_devices
        code = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import svm
from repro.launch.mesh import make_test_mesh
rng = np.random.default_rng(0)
x = rng.normal(size=(256, 12)).astype(np.float32)
y = np.where(rng.random(256) > 0.5, 1.0, -1.0).astype(np.float32)
w0 = jnp.zeros(12)
mesh = make_test_mesh((8,), ("data",))
wv = svm.dms(w0, x, y, workers=8, epochs=3, block_size=4, backend="vmap")
with jax.set_mesh(mesh):
    ws = svm.dms(w0, x, y, workers=8, epochs=3, block_size=4,
                 backend="shard_map", mesh=mesh)
np.testing.assert_allclose(np.asarray(wv), np.asarray(ws), rtol=1e-5, atol=1e-6)
print("OK")
"""
        assert "OK" in run_with_devices(code)
