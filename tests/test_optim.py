"""Optimizers and schedules (incl. the paper's α = 1/(1+t))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # optional dev dep: property tests skip
    from conftest import given, settings, st

from repro.config import OptimizerConfig
from repro.optim import apply_updates, init_opt_state, make_schedule


def _params():
    rng = np.random.default_rng(0)
    return {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(3,)), jnp.float32)}


def _grads():
    rng = np.random.default_rng(1)
    return {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(3,)), jnp.float32)}


class TestSchedules:
    def test_paper_inverse(self):
        s = make_schedule(OptimizerConfig(schedule="paper_inverse",
                                          learning_rate=1.0))
        for t in (0, 1, 9, 99):
            assert float(s(jnp.int32(t))) == pytest.approx(1.0 / (1 + t))

    def test_cosine_endpoints(self):
        cfg = OptimizerConfig(schedule="cosine", learning_rate=1e-3,
                              warmup_steps=10, total_steps=100)
        s = make_schedule(cfg)
        assert float(s(jnp.int32(0))) == 0.0
        assert float(s(jnp.int32(10))) == pytest.approx(1e-3)
        assert float(s(jnp.int32(100))) == pytest.approx(0.0, abs=1e-9)

    def test_constant(self):
        s = make_schedule(OptimizerConfig(schedule="constant",
                                          learning_rate=0.5))
        assert float(s(jnp.int32(1234))) == 0.5


class TestAdamW:
    def test_first_step_matches_reference(self):
        cfg = OptimizerConfig(name="adamw", learning_rate=1e-2, beta1=0.9,
                              beta2=0.999, eps=1e-8, schedule="constant")
        p, g = _params(), _grads()
        st0 = init_opt_state(cfg, p)
        p1, st1 = apply_updates(cfg, g, st0, p, jnp.int32(0))
        # bias-corrected first step ≈ −lr · sign-ish(g)
        for k in p:
            m = 0.1 * np.asarray(g[k]) / (1 - 0.9)
            v = 0.001 * np.asarray(g[k]) ** 2 / (1 - 0.999)
            want = np.asarray(p[k]) - 1e-2 * m / (np.sqrt(v) + 1e-8)
            np.testing.assert_allclose(np.asarray(p1[k]), want, rtol=1e-4)

    def test_weight_decay_decoupled(self):
        cfg = OptimizerConfig(name="adamw", learning_rate=1e-2,
                              weight_decay=0.1, schedule="constant")
        p = _params()
        zero_g = jax.tree.map(jnp.zeros_like, p)
        st0 = init_opt_state(cfg, p)
        p1, _ = apply_updates(cfg, zero_g, st0, p, jnp.int32(0))
        for k in p:
            np.testing.assert_allclose(np.asarray(p1[k]),
                                       np.asarray(p[k]) * (1 - 1e-3),
                                       rtol=1e-5)

    def test_bf16_moments(self):
        cfg = OptimizerConfig(name="adamw", moment_dtype="bfloat16")
        st0 = init_opt_state(cfg, _params())
        assert all(x.dtype == jnp.bfloat16
                   for x in jax.tree.leaves(st0))


class TestClip:
    @settings(deadline=None, max_examples=20)
    @given(scale=st.floats(0.1, 100.0))
    def test_global_norm_clip(self, scale):
        cfg = OptimizerConfig(name="sgd", learning_rate=1.0, grad_clip=1.0,
                              schedule="constant")
        p = {"w": jnp.zeros(8)}
        g = {"w": jnp.full(8, scale / np.sqrt(8), jnp.float32)}
        p1, _ = apply_updates(cfg, g, {}, p, jnp.int32(0))
        step_norm = float(jnp.linalg.norm(p1["w"]))
        assert step_norm <= min(scale, 1.0) * 1.01


class TestMomentum:
    def test_accumulates(self):
        cfg = OptimizerConfig(name="momentum", learning_rate=1.0,
                              momentum=0.5, schedule="constant")
        p = {"w": jnp.zeros(2)}
        g = {"w": jnp.ones(2)}
        st0 = init_opt_state(cfg, p)
        p1, st1 = apply_updates(cfg, g, st0, p, jnp.int32(0))
        p2, st2 = apply_updates(cfg, g, st1, p1, jnp.int32(1))
        # mu1 = 1, step1 = -1; mu2 = 1.5, step2 = -1.5 → p2 = -2.5
        np.testing.assert_allclose(np.asarray(p2["w"]), -2.5, rtol=1e-6)


class TestElasticContinuation:
    def test_grow_replicas_and_continue(self):
        """A local-SGD state saved at K=2 replicas restores at K=4 and
        keeps training — the elastic-resize path end to end."""
        from conftest import run_with_devices
        code = """
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import rescale_replicated_state
from repro.config import (MeshConfig, OptimizerConfig, SyncConfig,
                          TrainConfig, DataConfig, get_smoke)
from repro.core import local_sgd as LS
from repro.models.registry import build_model

def make(pods):
    mesh = jax.make_mesh((pods, 8 // pods, 1), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    mesh_cfg = MeshConfig(shape=(pods, 8 // pods, 1),
                          axis_names=("pod", "data", "model"),
                          replica_axis="pod")
    cfg = TrainConfig(model=get_smoke("internlm2-1.8b"), mesh=mesh_cfg,
                      sync=SyncConfig(strategy="hierarchical", period=2),
                      optimizer=OptimizerConfig(name="sgd", learning_rate=0.05),
                      data=DataConfig(seq_len=16, global_batch=8))
    return mesh, cfg

rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 512, (2, 8, 16)), jnp.int32),
         "targets": jnp.asarray(rng.integers(0, 512, (2, 8, 16)), jnp.int32)}

mesh2, cfg2 = make(2)
model = build_model(cfg2.model)
with jax.set_mesh(mesh2):
    state = LS.init_state(model, cfg2, jax.random.key(0), replicas=2)
    step2 = jax.jit(LS.make_local_sgd_block(model, cfg2, mesh2))
    state, m = step2(state, batch)
    l2 = float(m["loss"])

# elastic grow 2 → 4 replicas (average then broadcast)
host = jax.device_get(state)
resized = {
    "params": rescale_replicated_state(host["params"], 2, 4),
    "opt": rescale_replicated_state(host["opt"], 2, 4),
    "sync": rescale_replicated_state(host["sync"], 2, 4),
    "step": host["step"],
}
mesh4, cfg4 = make(4)
with jax.set_mesh(mesh4):
    step4 = jax.jit(LS.make_local_sgd_block(model, cfg4, mesh4))
    state4 = jax.tree.map(jnp.asarray, resized)
    state4, m4 = step4(state4, batch)
    l4 = float(m4["loss"])
assert np.isfinite(l4) and l4 < l2 + 0.5, (l2, l4)
print("OK", l2, l4)
"""
        out = run_with_devices(code, n_devices=8)
        assert "OK" in out
