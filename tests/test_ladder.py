"""H-ladder runtime (ISSUE 5): pre-compiled rungs, exact mid-run switches,
zero recompiles after warmup, rung checkpointing, controller ladder mode."""
import dataclasses

import numpy as np
import pytest

from conftest import run_with_devices
from repro.config import MeshConfig, SyncConfig, TrainConfig
from repro.core.autotune import AdaptiveController, snap_to_ladder


class TestLadderConfig:
    def test_geometric_ladder(self):
        cfg = SyncConfig(strategy="periodic", period=8, adapt_h_max=64)
        assert cfg.ladder_rungs() == (1, 2, 4, 8, 16, 32, 64)

    def test_period_always_included(self):
        cfg = SyncConfig(strategy="periodic", period=24, adapt_h_max=8)
        assert cfg.ladder_rungs() == (1, 2, 4, 8, 24)

    def test_explicit_ladder_overrides(self):
        cfg = SyncConfig(strategy="periodic", period=3,
                         adapt_ladder=(1, 3, 9, 27))
        assert cfg.ladder_rungs() == (1, 3, 9, 27)

    def test_validate_rejects_bad_ladder(self):
        from repro.core import sync as S
        with pytest.raises(ValueError, match="adapt_ladder"):
            S.validate(SyncConfig(strategy="periodic", adaptive=True,
                                  adapt_ladder=(0, 2)))
        with pytest.raises(ValueError, match="rung_hysteresis"):
            S.validate(SyncConfig(strategy="periodic", adaptive=True,
                                  adapt_rung_hysteresis=0))


class TestSnapToLadder:
    def test_log_nearest(self):
        ladder = (1, 2, 4, 8, 16)
        assert snap_to_ladder(1, ladder) == 1
        assert snap_to_ladder(3, ladder) == 4   # log(3) nearer log(4)
        assert snap_to_ladder(6, ladder) == 8   # log(6) nearer log(8)
        assert snap_to_ladder(100, ladder) == 16
        # exact log-midpoint ties resolve to the smaller rung (more
        # frequent sync is the safe side)
        assert snap_to_ladder(4, (2, 8)) == 2

    def test_empty_ladder_raises(self):
        with pytest.raises(ValueError):
            snap_to_ladder(4, ())


def _ctrl(**kw):
    cfg = SyncConfig(strategy="periodic")
    kw.setdefault("param_bytes_per_chip", 10**8)
    kw.setdefault("replicas", 8)
    kw.setdefault("lr", 1e-6)
    return AdaptiveController(cfg, **kw)


class TestControllerLadderMode:
    def test_moves_only_onto_rungs(self):
        c = _ctrl(h0=1, adapt_every=1, ladder=(1, 2, 4, 8, 16, 32, 64))
        c.telemetry._skip_step = c.telemetry._skip_sync = 0
        c.observe_block(step_s=1e-3, sync_s=0.9e-3)   # re-solve: H=18-ish
        assert c.h in (1, 2, 4, 8, 16, 32, 64)
        assert c.h > 1

    def test_h0_snaps_into_ladder(self):
        c = _ctrl(h0=24, ladder=(1, 2, 4, 8, 16, 32))
        assert c.h == 32                    # log-nearest rung

    def test_rung_hysteresis_holds_adjacent_moves(self):
        # solved H snaps one rung up; hysteresis of 2 rungs holds it
        c = _ctrl(h0=8, adapt_every=1, ladder=(1, 2, 4, 8, 16, 32),
                  rung_hysteresis=2)
        c.telemetry._skip_step = c.telemetry._skip_sync = 0
        c.observe_block(step_s=1e-3, sync_s=16 * 0.05 * 1e-3)
        assert c.h == 8
        # a 2-rung jump clears the threshold
        c.observe_block(step_s=1e-3, sync_s=64 * 0.05 * 1e-3)
        assert c.h > 8

    def test_ladder_caps_h_max(self):
        c = _ctrl(h0=1, adapt_every=1, ladder=(1, 2, 4))
        c.telemetry._skip_step = c.telemetry._skip_sync = 0
        c.observe_block(step_s=1e-6, sync_s=10.0)   # absurd sync time
        assert c.h == 4                     # top rung, not h_max=1024

    def test_analytic_fallback_moves_from_block_times_alone(self):
        """Single-rung block telemetry (the LM path before any move)
        re-solves with the analytic T_sync — the first move must not
        deadlock on the two-rung least-squares requirement."""
        c = _ctrl(h0=8, adapt_every=1, ladder=(1, 2, 4, 8),
                  param_bytes_per_chip=10**4)
        c.telemetry._skip_block = 0
        # huge measured per-step time vs tiny analytic sync ⇒ H=1
        c.observe_block(block_s=8 * 0.05)
        assert c.h == 1
        assert c.history[-1][1] == 1


class TestAdaptiveReportReplicaAxisFallback:
    """ISSUE 5 bugfix satellite: the end-of-run adaptive report must use
    the same ``or "pod"`` replica-axis fallback as build_trainer instead
    of pricing a nonexistent axis."""

    def _report(self, mesh_cfg):
        import jax
        from repro.core.telemetry import BlockTelemetry
        from repro.launch.mesh import make_test_mesh
        from repro.launch.train import adaptive_report
        mesh = make_test_mesh((1, 1))
        cfg = TrainConfig(mesh=mesh_cfg,
                          sync=SyncConfig(strategy="sync_every_step",
                                          adaptive=True))
        tel = BlockTelemetry(warmup=0)
        for _ in range(3):
            tel.record_step_time(1e-3)
            tel.record_sync_time(2e-3)
        with jax.set_mesh(mesh):
            return adaptive_report(cfg, mesh, tel)

    def test_unset_replica_axis(self):
        rep = self._report(MeshConfig(shape=(1, 1),
                                      axis_names=("data", "model")))
        assert rep["recommended_h"] is not None

    def test_none_replica_axis(self):
        mesh_cfg = dataclasses.replace(
            MeshConfig(shape=(1, 1), axis_names=("data", "model")),
            replica_axis=None)
        rep = self._report(mesh_cfg)
        assert rep["recommended_h"] is not None

    def test_matches_pod_fallback_pricing(self):
        rep_unset = self._report(MeshConfig(shape=(1, 1),
                                            axis_names=("data", "model")))
        rep_pod = self._report(MeshConfig(shape=(1, 1),
                                          axis_names=("data", "model"),
                                          replica_axis="pod"))
        assert rep_unset["recommended_h"] == rep_pod["recommended_h"]


class TestSwitchExactness:
    """Tentpole acceptance: a ladder switch at a sync boundary must be
    bit-identical to a fresh run at the new H from the flushed model —
    across overlap × compression × gossip_async."""

    def test_switch_state_equals_fresh_init(self):
        code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.config import SyncConfig, TrainConfig
from repro.core import local_sgd as LS
from repro.core import sync as S

k, d, nb = 4, 16, 5
mesh = jax.make_mesh((k,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
w0 = rng.normal(size=(d,)).astype(np.float32)
upds = jnp.asarray(rng.normal(size=(nb, k, d)).astype(np.float32))

def make_step(cfg):
    def body(p, st, u):
        lp = {"w": p["w"][0]}
        lst = jax.tree.map(lambda x: x[0], st)
        end = {"w": lp["w"] + u[0]}
        np_, nst = S.sync_point(lp, end, lst, cfg, "pod")
        re = lambda t: jax.tree.map(lambda x: x[None], t)
        return re(np_), re(nst)
    f = jax.shard_map(body, mesh=mesh,
                      in_specs=(P("pod"), P("pod"), P("pod")),
                      out_specs=(P("pod"), P("pod")),
                      axis_names={"pod"}, check_vma=False)
    return jax.jit(f)

cfgs = [
    SyncConfig(strategy="periodic"),
    # blocking/all with compression: finalize_state no-ops but the EF
    # residual is live state — the switch must fold its replica mean and
    # zero it or it is not fresh-init-identical (review finding)
    SyncConfig(strategy="periodic", compression="int8"),
    SyncConfig(strategy="periodic", compression="int16"),
    SyncConfig(strategy="periodic", overlap="delayed", compression="int8"),
    SyncConfig(strategy="periodic", overlap="delayed", compression="int16",
               topology="ring"),
    SyncConfig(strategy="periodic", overlap="chunked", chunks=2),
    SyncConfig(strategy="periodic", overlap="chunked", chunks=2,
               compression="int8", topology="pairwise"),
    SyncConfig(strategy="periodic", topology="ring", gossip_async=True),
    SyncConfig(strategy="periodic", topology="pairwise", gossip_async=True,
               compression="int8"),
]
eq = lambda a, b: jax.tree.map(lambda x, y: np.testing.assert_array_equal(
    np.asarray(x), np.asarray(y)), a, b)
with jax.set_mesh(mesh):
    for cfg in cfgs:
        tc = TrainConfig(sync=cfg)
        step = make_step(cfg)
        bcast = lambda x: jnp.broadcast_to(x, (k,) + x.shape)
        p = {"w": bcast(jnp.asarray(w0))}
        st = jax.tree.map(bcast, S.init_sync_state(cfg, {"w": jnp.asarray(w0)}))
        for t in range(2):                       # drift + live sync state
            p, st = step(p, st, upds[t])
        sw = LS.ladder_switch_state({"params": p, "sync": st}, tc)

        # 1) all replicas collapsed to one flushed model
        wsw = np.asarray(sw["params"]["w"])
        assert np.all(wsw == wsw[:1]), cfg
        # 2) sync state is bit-identical to a FRESH init at the flushed
        #    model (counters restarted, buffers re-seeded)
        fresh = jax.tree.map(
            bcast, S.init_sync_state(cfg, {"w": jnp.asarray(wsw[0])}))
        eq(sw["sync"], fresh)
        # 3) continuing from the switch == continuing from the fresh
        #    state, bit-exact (the new-H run sees identical inputs)
        pa, sa = sw["params"], sw["sync"]
        pb, sb = {"w": bcast(jnp.asarray(wsw[0]))}, fresh
        for t in range(2, nb):
            pa, sa = step(pa, sa, upds[t])
            pb, sb = step(pb, sb, upds[t])
        eq(pa, pb)
        eq(sa, sb)
print("OK")
"""
        assert "OK" in run_with_devices(code, n_devices=4)


class TestTrainerLadder:
    """The live LM path: pre-compiled rungs + compiled switch, exactness
    vs a fresh jit at the new H, and ZERO XLA compiles after warmup."""

    def test_ladder_switch_exact_and_no_recompiles(self):
        code = """
import sys
sys.argv = ["t"]
import jax, jax.numpy as jnp, numpy as np
from repro.config import DataConfig, TrainConfig, get_smoke
from repro.config.base import replace as cfg_replace
from repro.core import local_sgd as LS
from repro.launch.mesh import make_test_mesh, test_mesh_config
from repro.launch.train import build_trainer
from repro.data.pipeline import DataPipeline

n_dev = 4
mesh = make_test_mesh((n_dev, 1))
mesh_cfg = cfg_replace(test_mesh_config((n_dev, 1)), replica_axis="data")
cfg = TrainConfig(model=get_smoke("smollm-360m"), mesh=mesh_cfg,
                  data=DataConfig(seq_len=32, global_batch=n_dev * 2),
                  steps=8)
cfg = cfg_replace(cfg, **{"sync.strategy": "periodic", "sync.period": 2,
                          "sync.adaptive": True,
                          "sync.adapt_ladder": (2, 4)})

step, state, make_pipeline, model, telemetry, ladder = build_trainer(
    cfg, mesh)
assert ladder is not None and sorted(ladder.rungs) == [2, 4]
ctr = ladder.compile_counter
assert ctr is not None and ctr.count > 0      # warmup compiles counted

# drive 3 blocks at rung 2, switch, 2 blocks at rung 4 — all compiled
pipe = DataPipeline(cfg.data, cfg.model)
def block(h):
    mbs = [pipe.next_host() for _ in range(h)]
    return {k: np.stack([m[k] for m in mbs]) for k in mbs[0]}

with jax.set_mesh(mesh):
    for _ in range(3):
        state, _m = ladder.rungs[2](state, block(2))
    at_switch = jax.device_get(state)         # host snapshot pre-donation
    state = ladder.switch_fn(state)
    post_switch = jax.device_get(state)
    blocks4 = [block(4) for _ in range(2)]
    for b in blocks4:
        state, _m = ladder.rungs[4](state, b)
    jax.block_until_ready(jax.tree.leaves(state))
assert ctr.since_mark == 0, f"recompiled after warmup: {ctr.since_mark}"

# reference 1: the compiled switch must agree with the eager transform
# (the definition of "launch fresh at the new H from the flushed model")
with jax.set_mesh(mesh):
    ref = jax.device_get(LS.ladder_switch_state(
        jax.tree.map(jnp.asarray, at_switch), cfg))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=0, atol=1e-6),
        post_switch, ref)
    # reference 2: continuing at the new H under the pre-compiled rung
    # must be BIT-identical to a freshly traced jit at that H consuming
    # the same switched state and blocks
    from repro.sharding import rules_for
    fresh_step = jax.jit(LS.make_train_step(model, cfg, mesh,
                                            rules_for(cfg.mesh, mesh)))
    sref = jax.tree.map(jnp.asarray, post_switch)
    for b in blocks4:
        sref, _m = fresh_step(sref, b)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)),
        jax.device_get(state), jax.device_get(sref))

# compiled rungs refuse foreign shapes instead of recompiling
try:
    ladder.rungs[2](state, block(4))
    raise SystemExit("wrong-shape call did not raise")
except (TypeError, ValueError):
    pass
print("OK")
"""
        assert "OK" in run_with_devices(code, n_devices=4, timeout=900)


class TestAdaptiveSmokeCLI:
    def test_adaptive_smoke_moves_h_with_zero_recompiles(self):
        """Mirror of the CI ``adaptive-smoke`` job: the full driver on 8
        host devices must move H mid-run and report zero XLA compiles
        after ladder warmup, with the trajectory in the output JSON."""
        code = """
import sys
sys.argv = ["train", "--arch", "smollm-360m", "--smoke", "--steps", "10",
            "--set", "sync.strategy=periodic", "--set", "sync.period=4",
            "--set", "mesh.replica_axis=data",
            "--set", "sync.adaptive=true", "--set", "sync.adapt_every=2",
            "--set", "sync.adapt_h_max=8"]
from repro.launch import train
train.main()
"""
        out = run_with_devices(code, n_devices=8, timeout=900)
        import json
        rec = json.loads(out.strip().splitlines()[-1])
        ad = rec["adaptive"]
        assert ad["switches"] >= 1, ad["h_trajectory"]
        assert ad["compiles_after_warmup"] == 0, ad
        assert ad["h_trajectory"][0][1] == 4
        assert len(ad["h_trajectory"]) == ad["switches"] + 1
        assert ad["telemetry"]["per_rung"]      # per-rung block telemetry


class TestMidLadderCheckpoint:
    """Satellite: a checkpoint taken mid-ladder must restore the active
    rung and replay bit-exactly (scripted controller — the adaptive
    controller's telemetry is deliberately not checkpointed)."""

    class Scripted:
        def __init__(self, h0, script):
            self.h = h0
            self.script = dict(script)
            self._blocks = 0
            self.history = [(0, h0)]

        def observe_block(self, **kw):
            self._blocks += 1
            if self._blocks in self.script:
                self.h = self.script[self._blocks]
                self.history.append((self._blocks, self.h))
            return self.h

    def _runner(self, tmp_path, name, fault_cfg):
        import jax.numpy as jnp
        from repro.checkpoint import CheckpointManager
        from repro.config import CheckpointConfig, DataConfig, ModelConfig
        from repro.data.pipeline import DataPipeline
        from repro.launch.train import _Blocked
        from repro.runtime import LadderRuntime, StepRunner

        data_cfg = DataConfig(seq_len=8, global_batch=2, seed=3)
        model_cfg = ModelConfig(vocab_size=97)

        def make_rung(h):
            def fn(state, batch):
                m = jnp.mean(batch["tokens"].astype(jnp.float32))
                return ({"w": state["w"] * 0.9 + 0.1 * m}, {"loss": m})
            return fn

        ctrl = self.Scripted(2, {2: 1})
        ladder = LadderRuntime({1: make_rung(1), 2: make_rung(2)},
                               switch_fn=lambda s: dict(s), controller=ctrl)

        def make_pipeline(start):
            return _Blocked(DataPipeline(data_cfg, model_cfg,
                                         start_step=start), ladder.h)

        ckpt = CheckpointManager(CheckpointConfig(
            directory=str(tmp_path / name), interval_steps=3))
        runner = StepRunner(None, ckpt, fault_cfg, ckpt_interval=3,
                            make_pipeline=make_pipeline, ladder=ladder)
        return runner, ladder

    def test_restore_rung_and_bitexact_replay(self, tmp_path):
        import jax.numpy as jnp
        from repro.config import FaultToleranceConfig

        r_a, lad_a = self._runner(tmp_path, "a", FaultToleranceConfig())
        sa, _ = r_a.run({"w": jnp.float32(1.0)}, 0, 6)

        r_b, lad_b = self._runner(
            tmp_path, "b", FaultToleranceConfig(inject_failure_at=4))
        sb, _ = r_b.run({"w": jnp.float32(1.0)}, 0, 6)

        assert r_b.restarts == 1
        assert lad_a.h == lad_b.h == 1          # rung restored from ckpt
        # the restore path appended the restored rung to the trajectory
        assert lad_b.trajectory[-1][1] == 1
        np.testing.assert_array_equal(np.asarray(sa["w"]),
                                      np.asarray(sb["w"]))

    def test_checkpoint_extra_records_rung(self, tmp_path):
        import jax.numpy as jnp
        from repro.config import FaultToleranceConfig

        runner, ladder = self._runner(tmp_path, "c", FaultToleranceConfig())
        runner.run({"w": jnp.float32(1.0)}, 0, 6)
        _state, extra = runner.ckpt.restore({"w": jnp.float32(0.0)})
        assert extra["ladder"]["h"] == 1

    def test_restore_rejects_uncompiled_rung(self):
        ladder_ctrl = self.Scripted(1, {})
        from repro.runtime import LadderRuntime
        lad = LadderRuntime({1: lambda s, b: (s, {})},
                            switch_fn=lambda s: s, controller=ladder_ctrl)
        with pytest.raises(ValueError, match="not in compiled ladder"):
            lad.restore({"h": 16})


class TestDmsLadder:
    """SVM path: pre-compiled block-size ladder + exact carry switch."""

    def test_dms_ladder_switch_exact(self):
        code = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import svm

k, d = 4, 8
mesh = jax.make_mesh((k,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
w0 = jnp.asarray(rng.normal(size=(d,)), jnp.float32)

cases = [
    dict(overlap="none", topology="all"),
    dict(overlap="delayed", topology="all"),
    dict(overlap="chunked", chunks=2, topology="all"),
    dict(overlap="none", topology="ring"),
    dict(overlap="none", topology="ring", gossip_async=True),
]
def data(bs):
    x = jnp.asarray(rng.normal(size=(k, bs, d)), jnp.float32)
    y = jnp.asarray(np.sign(rng.normal(size=(k, bs))), jnp.float32)
    return x, y

eq = lambda a, b: jax.tree.map(lambda x, y: np.testing.assert_array_equal(
    np.asarray(x), np.asarray(y)), a, b)
with jax.set_mesh(mesh):
    for kw in cases:
        ladder = svm.dms_block_ladder(mesh, "data", d=d, workers=k,
                                      block_sizes=(2, 4), **kw)
        carry = svm.dms_stepper_init(w0, k, **kw)
        blocks2 = [data(2) for _ in range(3)]
        for x, y in blocks2:
            carry = ladder[2](carry, x, y, jnp.float32(0.5))
        sw = svm.dms_ladder_switch(jax.device_get(carry), d=d, **kw)
        # the flush collapsed the workers (all rows equal) onto the
        # worker mean (sanity-check against an independent numpy mean)
        wsw = np.asarray(sw["w"])
        assert np.all(wsw == wsw[:1]), kw
        wk = np.asarray(carry["w"]).astype(np.float32)
        if kw.get("overlap") == "delayed":
            wk = wk + np.asarray(carry["pending"], np.float32)
        np.testing.assert_allclose(wsw[0, :d], wk.mean(axis=0)[:d],
                                   rtol=0, atol=1e-6)
        # switch == fresh stepper init at the flushed model, bit-exact
        fresh = svm.dms_stepper_init(jnp.asarray(wsw[0, :d]), k, **kw)
        eq(sw, fresh)
        # continuing at the new rung from the switch == from fresh, and
        # the compiled rung accepts the switched carry
        ca, cb = sw, fresh
        for _ in range(2):
            x, y = data(4)
            ca = ladder[4](ca, x, y, jnp.float32(0.25))
            cb = ladder[4](cb, x, y, jnp.float32(0.25))
        eq(ca, cb)
        # a compiled rung refuses foreign block sizes
        x, y = data(3)
        try:
            ladder[2](carry, x, y, jnp.float32(0.5))
            raise SystemExit("wrong-shape call did not raise")
        except (TypeError, ValueError):
            pass
print("OK")
"""
        assert "OK" in run_with_devices(code, n_devices=4, timeout=900)
