"""Chunked CE loss + the loop-aware HLO roofline parser."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.losses import ce_loss


class TestChunkedCE:
    def test_chunked_equals_unchunked(self):
        rng = np.random.default_rng(0)
        b, s, d, v = 2, 64, 16, 101
        x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
        table = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
        tgt = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
        full = ce_loss(x, table, tgt, chunk=0)
        chunked = ce_loss(x, table, tgt, chunk=16)
        np.testing.assert_allclose(float(full), float(chunked), rtol=1e-5)

    def test_chunked_gradient_matches(self):
        rng = np.random.default_rng(1)
        b, s, d, v = 2, 32, 8, 37
        x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
        table = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
        tgt = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
        g_full = jax.grad(lambda t: ce_loss(x, t, tgt, chunk=0))(table)
        g_chnk = jax.grad(lambda t: ce_loss(x, t, tgt, chunk=8))(table)
        np.testing.assert_allclose(np.asarray(g_full), np.asarray(g_chnk),
                                   rtol=1e-4, atol=1e-6)

    def test_mask_selects_positions(self):
        rng = np.random.default_rng(2)
        b, s, d, v = 1, 8, 4, 11
        x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
        table = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
        tgt = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
        mask = jnp.asarray([[1, 1, 1, 1, 0, 0, 0, 0]], jnp.float32)
        l_masked = ce_loss(x, table, tgt, mask=mask)
        l_prefix = ce_loss(x[:, :4], table, tgt[:, :4])
        np.testing.assert_allclose(float(l_masked), float(l_prefix),
                                   rtol=1e-5)

    def test_matches_naive_logsoftmax(self):
        rng = np.random.default_rng(3)
        b, s, d, v = 2, 4, 8, 13
        x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
        table = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
        tgt = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
        logits = x @ table.T
        logp = jax.nn.log_softmax(logits, axis=-1)
        want = -jnp.mean(jnp.take_along_axis(logp, tgt[..., None], -1))
        got = ce_loss(x, table, tgt)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


class TestRooflineParser:
    def _compile(self, fn, *args, n_dev=4):
        from conftest import run_with_devices
        raise NotImplementedError

    def test_scan_trip_count_multiplies_flops(self):
        """A 10-step scanned matmul must report ~10× one matmul's flops."""
        from conftest import run_with_devices
        code = """
import jax, jax.numpy as jnp
from repro.launch.roofline import analyze_hlo
M = 256
def one(x, w):
    return x @ w
def scanned(x, ws):
    def body(c, w):
        return c @ w, None
    y, _ = jax.lax.scan(body, x, ws)
    return y
x = jax.ShapeDtypeStruct((M, M), jnp.float32)
w1 = jax.ShapeDtypeStruct((M, M), jnp.float32)
wN = jax.ShapeDtypeStruct((10, M, M), jnp.float32)
f1 = analyze_hlo(jax.jit(one).lower(x, w1).compile().as_text(), 1).flops
fN = analyze_hlo(jax.jit(scanned).lower(x, wN).compile().as_text(), 1).flops
ratio = fN / f1
assert 9.5 < ratio < 10.5, ratio
assert abs(f1 - 2 * M**3) / (2 * M**3) < 0.01, f1
print("OK", ratio)
"""
        assert "OK" in run_with_devices(code, n_devices=1)

    def test_collective_bytes_counted(self):
        from conftest import run_with_devices
        code = """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.roofline import analyze_hlo
mesh = jax.make_mesh((4,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
def f(a, b):
    return a @ b          # contracting dim sharded → all-reduce
with jax.set_mesh(mesh):
    co = jax.jit(f, in_shardings=(P(None, "data"), P("data", None)),
                 out_shardings=P(None, None)).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
h = analyze_hlo(co.as_text(), 4)
assert "all-reduce" in h.collectives, h.collectives
n = 128 * 128 * 4
expect = 2 * n * 3 / 4            # ring AR wire bytes
got = h.collectives["all-reduce"]["wire_bytes"]
assert abs(got - expect) / expect < 0.01, (got, expect)
print("OK")
"""
        assert "OK" in run_with_devices(code, n_devices=4)
