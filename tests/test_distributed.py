"""Multi-device correctness (subprocess, 8 host devices): the sharded
execution paths must match their single-device oracles."""

from conftest import run_with_devices


class TestShardedMoE:
    def test_gshard_path_matches_jnp_oracle(self):
        code = """
import jax, jax.numpy as jnp, numpy as np
from repro.config import ModelConfig, MoEConfig, MeshConfig
from repro.models import moe as M
from repro.models.layers import init_params
from repro.sharding import rules_for, use_rules
from repro.launch.mesh import make_test_mesh
cfg = ModelConfig(name="t", family="moe", d_model=32, d_ff=16,
                  moe=MoEConfig(num_experts=8, top_k=2))
mesh = make_test_mesh((2, 4))
rules = rules_for(MeshConfig(shape=(2, 4), axis_names=("data", "model")), mesh)
params = init_params(M.moe_defs(cfg), jax.random.key(0))
x = jax.random.normal(jax.random.key(1), (8, 4096, 32), jnp.float32)
ref, aux_ref = M.moe_ffn(params, x, cfg)          # no mesh → jnp oracle
def loss(p, x):
    out, aux = M.moe_ffn(p, x, cfg)
    return jnp.mean(out ** 2) + 0.01 * aux
g_ref = jax.grad(loss)(params, x)
with jax.set_mesh(mesh), use_rules(rules):
    out, aux = jax.jit(lambda p, x: M.moe_ffn(p, x, cfg))(params, x)
    g = jax.jit(jax.grad(loss))(params, x)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-3, atol=1e-5)
np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)
for k in g:
    np.testing.assert_allclose(np.asarray(g[k]), np.asarray(g_ref[k]),
                               rtol=1e-3, atol=1e-5)
print("OK")
"""
        assert "OK" in run_with_devices(code)

    def test_onehot_path_matches_scatter_path(self):
        code = """
import jax, jax.numpy as jnp, numpy as np
from repro.config import ModelConfig, MoEConfig
from repro.models import moe as M
from repro.models.layers import init_params
cfg = ModelConfig(name="t", family="moe", d_model=16, d_ff=8,
                  moe=MoEConfig(num_experts=4, top_k=2))
params = init_params(M.moe_defs(cfg), jax.random.key(0))
x = jax.random.normal(jax.random.key(1), (2, 64, 16), jnp.float32)
a, aux_a = M.moe_ffn(params, x, cfg)                # scatter path (no mesh)
b, aux_b = M._moe_ffn_onehot(params, x, cfg, 1.25)  # dense one-hot path
np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
np.testing.assert_allclose(float(aux_a), float(aux_b), rtol=1e-5)
print("OK")
"""
        assert "OK" in run_with_devices(code, n_devices=1)


class TestShardedEmbed:
    def test_manual_vocab_parallel_matches_gather(self):
        code = """
import jax, jax.numpy as jnp, numpy as np
from repro.config import MeshConfig
from repro.models import layers as L
from repro.sharding import rules_for, use_rules
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((2, 4))
rules = rules_for(MeshConfig(shape=(2, 4), axis_names=("data", "model")), mesh)
v, d = 64, 16
table = jax.random.normal(jax.random.key(0), (v, d), jnp.float32)
tokens = jax.random.randint(jax.random.key(1), (8, 4096), 0, v)
want = table[tokens]
with jax.set_mesh(mesh), use_rules(rules):
    got = jax.jit(lambda t, tok: L.embed({"embedding": t}, tok,
                                         jnp.float32))(table, tokens)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                           atol=1e-6)
print("OK")
"""
        assert "OK" in run_with_devices(code)


class TestFlashDecode:
    def test_seq_sharded_decode_matches_local(self):
        code = """
import jax, jax.numpy as jnp, numpy as np
from repro.config import MeshConfig
from repro.models import attention as A
from repro.sharding import rules_for, use_rules
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((2, 4))
rules = rules_for(MeshConfig(shape=(2, 4), axis_names=("data", "model")), mesh)
b, s, h, kv, hd = 4, 64, 4, 2, 16
q = jax.random.normal(jax.random.key(0), (b, 1, h, hd), jnp.float32)
k = jax.random.normal(jax.random.key(1), (b, s, kv, hd), jnp.float32)
v = jax.random.normal(jax.random.key(2), (b, s, kv, hd), jnp.float32)
idx = jnp.int32(37)
want = A.decode_attention(q, k, v, idx)             # no mesh → local math
with jax.set_mesh(mesh), use_rules(rules):
    got = jax.jit(lambda q, k, v: A.decode_attention(q, k, v, idx))(q, k, v)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                           atol=1e-5)
print("OK")
"""
        assert "OK" in run_with_devices(code)


class TestDDPStep:
    def test_sharded_loss_matches_single_device(self):
        """One DDP step on a (4,2) mesh must match the unsharded step."""
        code = """
import jax, jax.numpy as jnp, numpy as np
from repro.config import (MeshConfig, OptimizerConfig, SyncConfig,
                          TrainConfig, DataConfig, get_smoke)
from repro.core import local_sgd as LS
from repro.models.registry import build_model
from repro.sharding import rules_for
from repro.launch.mesh import make_test_mesh
cfg0 = get_smoke("qwen2.5-3b")
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 512, (8, 32)), jnp.int32),
         "targets": jnp.asarray(rng.integers(0, 512, (8, 32)), jnp.int32)}

def run(shape, names):
    mesh = make_test_mesh(shape, names)
    mesh_cfg = MeshConfig(shape=shape, axis_names=names)
    cfg = TrainConfig(model=cfg0, mesh=mesh_cfg,
                      optimizer=OptimizerConfig(name="sgd", learning_rate=0.1),
                      data=DataConfig(seq_len=32, global_batch=8))
    model = build_model(cfg.model)
    with jax.set_mesh(mesh):
        state = LS.init_state(model, cfg, jax.random.key(0))
        step = LS.make_ddp_step(model, cfg, mesh)
        state, metrics = jax.jit(step)(state, batch)
        return float(metrics["loss"]), jax.device_get(state["params"])

l1, p1 = run((1, 1), ("data", "model"))
l8, p8 = run((4, 2), ("data", "model"))
assert abs(l1 - l8) / abs(l1) < 1e-3, (l1, l8)
for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p8)):
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-4)
print("OK", l1, l8)
"""
        assert "OK" in run_with_devices(code)
