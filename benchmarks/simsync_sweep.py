"""Simulated sync-schedule sweep + adaptive-controller validation.

The host CPU cannot show the paper's headline effect (its collectives
serialize), so this sweep replays the schedules on the simsync cluster
simulator instead — deterministic (fixed seeds), CPU-cheap, and grounded
in the same cost-model wire bytes as the real engine. Four sections:

  comm     — simulated comm time vs H across topology × overlap on the
             default DCN profile: the paper's Figs 13–15 shape (comm time
             ∝ 1/H; the H=1 → H_max reduction is the 16x–24x regime and
             beyond — the acceptance bar is ≥ 10x).
  straggler— transient-straggler decoupling: wall clock + exposed comm of
             all-reduce vs ring/pairwise gossip under delayed overlap on
             the dcn_transient profile (ROADMAP's "what the 2-core host
             cannot measure").
  async    — unsynchronized-round gossip (``gossip_async``): the
             ``async_decoupling`` acceptance row compares the *clean-block*
             mean time (blocks whose worker did not itself straggle) on
             dcn_transient against the straggler-free profile — async must
             sit within 5% of it while the synchronized ring inherits its
             neighbors' straggles and degrades. Per-mode async-vs-sync
             rows land in the comm grid as ``overlap="async"``.
  adaptive — closed-loop AdaptiveController convergence vs the simulator's
             oracle-optimal H on distinct cluster profiles, with the
             (block, H) trajectory.
  artifacts— Chrome traces (all vs ring on the straggler profile) and a
             dependency-free SVG of the comm ∝ 1/H curve, under
             experiments/paper/ for the CI artifact upload.

Run via ``python -m benchmarks.run simsync_sweep [--json]``.
"""
from __future__ import annotations

import os
from typing import Dict, List

from benchmarks import record
from repro.config.base import SyncConfig
from repro.core.autotune import AdaptiveController
from repro.simsync import (PROFILES, oracle_h, save_chrome_trace, simulate,
                           simulate_adaptive)

STEPS = 2048              # fixed optimizer-step budget per simulated run
H_LADDER = (1, 2, 4, 8, 16, 32, 64)
SEED = 0                  # deterministic: CI asserts on these rows


def _svg_comm_vs_h(rows: List[Dict], path: str) -> str:
    """Dependency-free log–log SVG of comm time vs H (one polyline per
    topology, blocking overlap) — the Figs 13–15 regeneration artifact."""
    import math
    series: Dict[str, List] = {}
    for r in rows:
        if r.get("section") == "comm" and r["overlap"] == "none":
            series.setdefault(r["topology"], []).append(
                (r["H"], max(r["comm_exposed_s"], 1e-9)))
    w, h, pad = 480, 320, 48
    xs = [math.log2(hh) for s in series.values() for hh, _ in s]
    ys = [math.log10(c) for s in series.values() for _, c in s]
    x0, x1 = min(xs), max(xs) or 1
    y0, y1 = min(ys), max(ys)
    sx = lambda v: pad + (v - x0) / max(x1 - x0, 1e-9) * (w - 2 * pad)
    sy = lambda v: h - pad - (v - y0) / max(y1 - y0, 1e-9) * (h - 2 * pad)
    colors = {"all": "#1f77b4", "ring": "#d62728", "pairwise": "#2ca02c"}
    parts = [f'<svg xmlns="http://www.w3.org/2000/svg" width="{w}" '
             f'height="{h}" font-family="sans-serif" font-size="11">',
             f'<text x="{w//2}" y="16" text-anchor="middle">simulated comm '
             'time vs MSF period H (dcn_default, blocking)</text>',
             f'<line x1="{pad}" y1="{h-pad}" x2="{w-pad}" y2="{h-pad}" '
             'stroke="#333"/>',
             f'<line x1="{pad}" y1="{pad}" x2="{pad}" y2="{h-pad}" '
             'stroke="#333"/>',
             f'<text x="{w//2}" y="{h-12}" text-anchor="middle">H '
             '(log2)</text>']
    for i, (topo, pts) in enumerate(sorted(series.items())):
        pts = sorted(pts)
        poly = " ".join(f"{sx(math.log2(hh)):.1f},"
                        f"{sy(math.log10(c)):.1f}" for hh, c in pts)
        col = colors.get(topo, "#999")
        parts.append(f'<polyline points="{poly}" fill="none" '
                     f'stroke="{col}" stroke-width="2"/>')
        parts.append(f'<text x="{w-pad+4}" y="{pad+14*i}" fill="{col}">'
                     f'{topo}</text>')
        for hh, c in pts:
            parts.append(f'<circle cx="{sx(math.log2(hh)):.1f}" '
                         f'cy="{sy(math.log10(c)):.1f}" r="3" '
                         f'fill="{col}"/>')
    parts.append("</svg>")
    with open(path, "w") as f:
        f.write("\n".join(parts))
    return path


def run() -> List[str]:
    lines: List[str] = []
    rows: List[Dict] = []
    os.makedirs(record.OUT_DIR, exist_ok=True)

    # --- 1) comm time vs H: topology × overlap grid on the DCN profile --
    # (gossip topologies get an extra "async" mode row — the
    # unsynchronized-round exchange — so every async-vs-sync comparison
    # is one grid lookup away)
    prof = PROFILES["dcn_default"]
    for topo in ("all", "ring", "pairwise"):
        modes = ("none", "delayed", "chunked")
        if topo != "all":
            modes += ("async",)
        for overlap in modes:
            cfg = SyncConfig(strategy="periodic", topology=topo,
                             overlap="none" if overlap == "async"
                             else overlap,
                             gossip_async=overlap == "async")
            for h in H_LADDER:
                r = simulate(prof, cfg, h=h, steps=STEPS, seed=SEED)
                rows.append({"section": "comm", "profile": prof.name,
                             "topology": topo, "overlap": overlap, "H": h,
                             **{k: v for k, v in r.summary().items()
                                if k not in ("profile", "sync")}})
                lines.append(
                    f"simsync_sweep,comm,topo={topo} ov={overlap} H={h},"
                    f"{r.comm_exposed_s*1e3:.2f}")
    base = [r for r in rows if r["topology"] == "all"
            and r["overlap"] == "none"]
    red = base[0]["comm_exposed_s"] / base[-1]["comm_exposed_s"]
    rows.append({"section": "comm_reduction", "profile": prof.name,
                 "h_lo": H_LADDER[0], "h_hi": H_LADDER[-1],
                 "reduction_x": red})
    lines.append(f"simsync_sweep,comm_reduction,"
                 f"H={H_LADDER[0]}->H={H_LADDER[-1]},{red:.1f}x")

    # --- 2) transient-straggler decoupling (gossip + delayed overlap) ---
    pt = PROFILES["dcn_transient"]
    wall = {}
    for topo in ("all", "ring", "pairwise"):
        cfg = SyncConfig(strategy="periodic", topology=topo,
                         overlap="delayed")
        r = simulate(pt, cfg, h=16, steps=2 * STEPS, seed=SEED)
        wall[topo] = r.wall_clock_s
        rows.append({"section": "straggler", "profile": pt.name,
                     "topology": topo, "H": 16,
                     "wall_s": r.wall_clock_s,
                     "comm_exposed_s": r.comm_exposed_s})
        lines.append(f"simsync_sweep,straggler,topo={topo},"
                     f"{r.wall_clock_s:.3f}")
    lines.append(f"simsync_sweep,straggler_decoupling,ring_vs_all,"
                 f"{wall['all'] / wall['ring']:.3f}x")

    # --- 2b) async (unsynchronized-round) gossip decoupling -------------
    # clean-block mean = mean block time over (worker, block) samples whose
    # worker did NOT itself draw a transient straggle. Each mode is
    # compared against ITS OWN run on the straggler-free profile, so the
    # ratio isolates what the transient stragglers leak into clean blocks
    # (not the mode's inherent scheduling overhead): async gossip must
    # stay within 5% of its straggler-free self while the synchronized
    # ring's clean blocks inherit the neighborhood's straggles.
    ratios = {}
    for label, cfg_a in (
            ("async_ring", SyncConfig(strategy="periodic", topology="ring",
                                      gossip_async=True)),
            ("sync_ring", SyncConfig(strategy="periodic", topology="ring",
                                     overlap="delayed"))):
        base = simulate(PROFILES["dcn_default"], cfg_a, h=16,
                        steps=2 * STEPS, seed=SEED)
        r = simulate(pt, cfg_a, h=16, steps=2 * STEPS, seed=SEED)
        ratios[label] = (r.clean_block_mean_s / base.clean_block_mean_s,
                         base, r)
        rows.append({"section": "async", "profile": pt.name,
                     "mode": label, "H": 16,
                     "clean_block_base_s": base.clean_block_mean_s,
                     **{k: v for k, v in r.summary().items()
                        if k not in ("profile", "sync")}})
        lines.append(f"simsync_sweep,async,{label},"
                     f"clean_block_ms={r.clean_block_mean_s*1e3:.3f} "
                     f"base_ms={base.clean_block_mean_s*1e3:.3f} "
                     f"wall={r.wall_clock_s:.3f} "
                     f"stale_mean={r.stale_rounds_mean:.2f}")
    async_ratio = ratios["async_ring"][0]
    sync_ratio = ratios["sync_ring"][0]
    rows.append({"section": "async_decoupling", "profile": pt.name,
                 "H": 16,
                 "baseline_profile": "dcn_default",
                 "async_clean_block_s":
                     ratios["async_ring"][2].clean_block_mean_s,
                 "sync_ring_clean_block_s":
                     ratios["sync_ring"][2].clean_block_mean_s,
                 "async_clean_ratio": async_ratio,
                 "sync_ring_clean_ratio": sync_ratio,
                 "async_stale_rounds_mean":
                     ratios["async_ring"][2].stale_rounds_mean})
    lines.append(f"simsync_sweep,async_decoupling,"
                 f"async={async_ratio:.4f}x sync_ring={sync_ratio:.3f}x,"
                 f"{'OK' if async_ratio <= 1.05 < sync_ratio else 'FAIL'}")

    # --- 3) adaptive controller vs the simulator oracle -----------------
    cfg = SyncConfig(strategy="periodic")
    for name in ("dcn_default", "ici_pod", "dcn_straggler"):
        p = PROFILES[name]
        oh = oracle_h(p, cfg, target_overhead=0.05, steps=STEPS, seed=SEED)
        ctrl = AdaptiveController(cfg, param_bytes_per_chip=p.param_bytes,
                                  replicas=p.world,
                                  link_bw=p.link.bandwidth, h0=1,
                                  adapt_every=8, lr=1e-6)
        _, hist = simulate_adaptive(p, cfg, ctrl, blocks=200, seed=SEED + 1)
        rel = abs(ctrl.h - oh) / max(1, oh)
        rows.append({"section": "adaptive", "profile": name,
                     "oracle_h": oh, "controller_h": ctrl.h,
                     "rel_err": rel, "history": hist,
                     "telemetry": ctrl.telemetry.to_dict()})
        lines.append(f"simsync_sweep,adaptive,{name} oracle={oh},"
                     f"ctrl={ctrl.h} rel={rel:.3f}")

    # --- 3b) H-ladder parity: the trainer's rung-snapped controller -----
    # The live trainer moves H only onto its pre-compiled ladder rungs
    # (repro.runtime.ladder). Re-run the closed loop with the controller
    # in ladder mode on the same simulated telemetry and grade it against
    # the oracle snapped to the same ladder — the simulated counterpart
    # of the trajectory the adaptive-smoke CI job records on the real
    # path. Gate: within one rung of the snapped oracle.
    from repro.core.autotune import snap_to_ladder
    rungs = H_LADDER
    for name in ("dcn_default", "ici_pod"):
        p = PROFILES[name]
        oh = oracle_h(p, cfg, target_overhead=0.05, steps=STEPS, seed=SEED)
        ctrl = AdaptiveController(cfg, param_bytes_per_chip=p.param_bytes,
                                  replicas=p.world,
                                  link_bw=p.link.bandwidth, h0=1,
                                  adapt_every=8, lr=1e-6, ladder=rungs)
        _, hist = simulate_adaptive(p, cfg, ctrl, blocks=200, seed=SEED + 1)
        oracle_rung = snap_to_ladder(oh, rungs)
        rung_err = abs(rungs.index(ctrl.h) - rungs.index(oracle_rung))
        rows.append({"section": "ladder", "profile": name,
                     "ladder": list(rungs), "oracle_h": oh,
                     "oracle_rung": oracle_rung, "controller_h": ctrl.h,
                     "rung_err": rung_err, "history": hist})
        lines.append(f"simsync_sweep,ladder,{name} oracle_rung="
                     f"{oracle_rung},ctrl={ctrl.h} rung_err={rung_err}")

    # --- 4) artifacts: chrome traces + the Figs 13–15 SVG ---------------
    # (ring_async lanes show sends running under the next block's compute
    # with no stall lane at all — vs ring's one-hop-per-round stalls and
    # all's global barrier)
    for name_t, cfg_t in (
            ("all", SyncConfig(strategy="periodic", overlap="delayed")),
            ("ring", SyncConfig(strategy="periodic", topology="ring",
                                overlap="delayed")),
            ("ring_async", SyncConfig(strategy="periodic", topology="ring",
                                      gossip_async=True))):
        r = simulate(pt, cfg_t, h=16, blocks=24, seed=SEED,
                     record_timeline=True)
        path = os.path.join(record.OUT_DIR, f"simsync_trace_{name_t}.json")
        save_chrome_trace(path, r)
        lines.append(f"simsync_sweep,trace,{name_t},{path}")
    svg = _svg_comm_vs_h(rows, os.path.join(record.OUT_DIR,
                                            "simsync_comm_vs_h.svg"))
    lines.append(f"simsync_sweep,figure,comm_vs_h,{svg}")

    record.save("simsync_sweep", rows)
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
