"""Adaptive H-ladder trainer sweep (deterministic, simulator-driven).

The live trainer's H-ladder runtime (``repro.runtime.ladder``) moves the
MSF period mid-run by switching between pre-compiled rungs; this sweep
grades the *schedule* side of that loop on the simsync cluster simulator
(pure numpy, fixed seeds — bench-gate can diff it bit-for-bit), using the
same :class:`repro.core.autotune.AdaptiveController` in ladder mode and
the same host-observed calibration pair the real path feeds it. The real
path's own trajectory is exercised by the ``adaptive-smoke`` CI job
(``repro.launch.train --smoke`` with ``sync.adaptive=true``), whose
artifact carries the measured counterpart of these rows.

Sections (one JSON row each, bundled into ``BENCH_adaptive_trainer.json``):

  trajectory — per profile: the controller's (block, H) rung moves, its
               final rung vs the simulator oracle snapped to the same
               ladder (``rung_err`` gates at any-rise).
  per_rung   — simulated mean block time and block count per visited rung
               (the simulator analog of ``BlockTelemetry.per_rung``).
  comm_saved — exposed comm time of the adaptive run vs a fixed H=1 run
               of the same step budget: the paper's comm ∝ 1/H win,
               realized *online* by one run instead of a sweep.

Run via ``python -m benchmarks.run adaptive_trainer [--json]``.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks import record
from benchmarks.simsync_sweep import H_LADDER as LADDER
from repro.config.base import SyncConfig
from repro.core.autotune import AdaptiveController, snap_to_ladder
from repro.simsync import PROFILES, oracle_h, simulate
from repro.simsync.engine import ClusterSim

BLOCKS = 200
SEED = 0
PROFILE_NAMES = ("dcn_default", "dcn_straggler")


def _run_ladder_controller(profile, cfg: SyncConfig):
    """Closed loop on the simulator with per-rung bookkeeping.

    Mirrors :func:`repro.simsync.engine.simulate_adaptive` — including
    feeding the controller the host-observed (slowest-shard compute,
    barrier-free collective) pair — but also groups block durations by
    the rung they ran at, which is what the per-rung section reports.
    """
    ctrl = AdaptiveController(
        cfg, param_bytes_per_chip=profile.param_bytes,
        replicas=profile.world, link_bw=profile.link.bandwidth,
        h0=1, adapt_every=8, lr=1e-6, ladder=LADDER)
    sim = ClusterSim(profile, cfg, seed=SEED + 1)
    per_rung: Dict[int, Dict[str, float]] = {}
    for _ in range(BLOCKS):
        h = ctrl.h
        stats = sim.run_block(h)
        agg = per_rung.setdefault(h, {"block_s_sum": 0.0, "blocks": 0})
        agg["block_s_sum"] += stats.block_s
        agg["blocks"] += 1
        ctrl.observe_block(step_s=stats.compute_max_s / max(1, h),
                           sync_s=stats.sync_wire_s)
    return ctrl, sim.result(ctrl.h), per_rung


def run() -> List[str]:
    lines: List[str] = []
    rows: List[Dict] = []
    cfg = SyncConfig(strategy="periodic")

    for name in PROFILE_NAMES:
        profile = PROFILES[name]
        ctrl, result, per_rung = _run_ladder_controller(profile, cfg)
        oh = oracle_h(profile, cfg, target_overhead=0.05, steps=2048,
                      seed=SEED)
        oracle_rung = snap_to_ladder(oh, LADDER)
        rung_err = abs(LADDER.index(ctrl.h) - LADDER.index(oracle_rung))
        rows.append({
            "section": "trajectory", "profile": name,
            "ladder": list(LADDER), "history": list(ctrl.history),
            "final_h": ctrl.h, "switches": len(ctrl.history) - 1,
            "oracle_h": oh, "oracle_rung": oracle_rung,
            "rung_err": rung_err,
        })
        lines.append(
            f"adaptive_trainer,trajectory,{name} oracle_rung={oracle_rung},"
            f"final_h={ctrl.h} moves={len(ctrl.history) - 1} "
            f"rung_err={rung_err}")

        for h in sorted(per_rung):
            agg = per_rung[h]
            mean_s = agg["block_s_sum"] / max(1, agg["blocks"])
            rows.append({
                "section": "per_rung", "profile": name, "H": h,
                "block_s": mean_s, "blocks": agg["blocks"],
            })
            lines.append(f"adaptive_trainer,per_rung,{name} H={h},"
                         f"{mean_s * 1e3:.3f}")

        # fixed-H=1 run over the same optimizer-step budget the adaptive
        # run consumed — what the online schedule saved in exposed comm
        h1 = simulate(profile, cfg, h=1, steps=max(1, result.steps),
                      seed=SEED + 1)
        saved_x = h1.comm_exposed_s / max(result.comm_exposed_s, 1e-12)
        rows.append({
            "section": "comm_saved", "profile": name,
            "steps": result.steps,
            "h1_comm_exposed_s": h1.comm_exposed_s,
            "adaptive_comm_exposed_s": result.comm_exposed_s,
            "adaptive_wall_s": result.wall_clock_s,
            "h1_wall_s": h1.wall_clock_s,
            "saved_x": saved_x,
        })
        lines.append(f"adaptive_trainer,comm_saved,{name},"
                     f"{saved_x:.1f}")

    record.save("adaptive_trainer", rows)
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
