"""Benchmark entrypoint: ``python -m benchmarks.run [sweeps...] [--json]``.

One registry for every sweep — the paper-figure reproductions
(:mod:`benchmarks.paper_figs`), the simulated sync-schedule sweep
(:mod:`benchmarks.simsync_sweep`) and the roofline summary — dispatched
behind a single CLI. Each sweep prints ``name,label,value[,derived]`` CSV
lines; ``--json`` additionally bundles everything a sweep recorded (its
CSV lines plus every structured record section it saved) into one
``BENCH_<sweep>.json`` under ``--out``, so benchmark trajectories are
captured uniformly across sweeps.

    python -m benchmarks.run --list
    python -m benchmarks.run hinge_kernel overlap_sweep
    python -m benchmarks.run simsync_sweep --json --out experiments/bench
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Callable, Dict, List


def _roofline() -> List[str]:
    """Roofline summary assembled from the dry-run artifacts (if present)."""
    from benchmarks import roofline_table
    if not os.path.isdir("experiments/dryrun"):
        return ["roofline,SKIP,,no experiments/dryrun artifacts"]
    return list(roofline_table.csv_lines(roofline_table.load()))


def registry() -> Dict[str, Callable[[], List[str]]]:
    from benchmarks import adaptive_trainer, paper_figs, simsync_sweep
    reg: Dict[str, Callable[[], List[str]]] = dict(paper_figs.ALL)
    reg["simsync_sweep"] = simsync_sweep.run
    reg["adaptive_trainer"] = adaptive_trainer.run
    reg["roofline"] = _roofline
    return reg


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("sweeps", nargs="*",
                    help="sweep names (default: all registered sweeps)")
    ap.add_argument("--list", action="store_true",
                    help="list registered sweeps and exit")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<sweep>.json bundles under --out")
    ap.add_argument("--out", default="experiments/bench",
                    help="output directory for --json bundles")
    args = ap.parse_args(argv)

    reg = registry()
    if args.list:
        for name in sorted(reg):
            print(name)
        return

    names = args.sweeps or [n for n in reg if n != "roofline"]
    unknown = [n for n in names if n not in reg]
    if unknown:
        ap.error(f"unknown sweep(s) {unknown}; known: {sorted(reg)}")

    from benchmarks import record
    for name in names:
        record.take_saved()          # drop any stale registrations
        lines = reg[name]()
        for line in lines:
            print(line)
        if args.json:
            os.makedirs(args.out, exist_ok=True)
            bundle = {"sweep": name, "csv": lines,
                      "records": record.take_saved()}
            path = os.path.join(args.out, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump(bundle, f, indent=1)
            print(f"{name},BENCH,,{path}")

    # historical default: append the roofline summary when the dry-run
    # artifacts exist and it wasn't explicitly requested
    if "roofline" not in names and os.path.isdir("experiments/dryrun"):
        for line in _roofline():
            print(line)


if __name__ == "__main__":
    main()
