"""Benchmark entrypoint: ``python -m benchmarks.run``.

One section per paper table/figure (benchmarks.paper_figs) plus the
roofline summary assembled from the dry-run artifacts. Prints
``name,label,value,derived`` CSV lines.
"""
from __future__ import annotations

import os
import sys


def main() -> None:
    # keep benchmarks on the real single device (no fake device count)
    from benchmarks import paper_figs, roofline_table

    which = sys.argv[1:] or list(paper_figs.ALL)
    for name in which:
        if name in paper_figs.ALL:
            for line in paper_figs.ALL[name]():
                print(line)

    if os.path.isdir("experiments/dryrun"):
        recs = roofline_table.load()
        for line in roofline_table.csv_lines(recs):
            print(line)


if __name__ == "__main__":
    main()
