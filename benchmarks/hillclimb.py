"""§Perf hillclimb driver: re-lower the three selected cells under each
candidate change and record the roofline deltas.

Cells (chosen per the brief: worst roofline fraction, most
collective-bound, most representative of the paper's technique):

  A. smollm-360m × train_4k (16×16)      — worst MFU-bound / useful ratio
  B. phi3.5-moe  × prefill_32k (16×16)   — most collective-bound
  C. qwen3-moe   × train_4k (2×16×16)    — the MSF/DCN cell: paper-faithful
     every-step sync vs the paper's periodic schedule vs beyond-paper
     (int8 delta compression)

Usage: PYTHONPATH=src python -m benchmarks.hillclimb [A|B|C ...]
Writes experiments/perf/<cell>__<variant>.json.
"""
from __future__ import annotations

import json
import os
import sys


def _run(tag: str, arch: str, shape: str, **kw):
    from repro.launch.dryrun import run_cell
    rec = run_cell(arch, shape, verbose=False, **kw)
    os.makedirs("experiments/perf", exist_ok=True)
    with open(f"experiments/perf/{tag}.json", "w") as f:
        json.dump(rec, f, indent=1)
    if rec["status"] != "ok":
        print(f"{tag}: {rec['status']} {rec.get('error', '')[:200]}")
        return rec
    t = rec["roofline"]
    h = max(1, rec.get("opt_steps_per_call", 1))
    print(f"{tag}: compute {t['compute_s']/h:8.3f}s | memory "
          f"{t['memory_s']/h:8.3f}s | collective {t['collective_s']/h:8.3f}s "
          f"| {t['dominant']:>10} | GB/dev {rec['resident_bytes_per_device']/1e9:6.2f} "
          f"| MFU-bound {t['mfu_bound']*h*100:5.2f}%")
    return rec


def cell_a():
    print("== Cell A: smollm-360m × train_4k (16×16) ==")
    _run("A1_substrate", "smollm-360m", "train_4k", multi_pod=False)
    _run("A2_context_parallel_attn", "smollm-360m", "train_4k",
         multi_pod=False, rule_overrides={"attn_q_seq": ("model",)})
    _run("A3_cp_attn_remat_dots", "smollm-360m", "train_4k",
         multi_pod=False, rule_overrides={"attn_q_seq": ("model",)},
         remat="dots")


def cell_b():
    print("== Cell B: phi3.5-moe × prefill_32k (16×16) ==")
    _run("B1_flat_head_attn", "phi3.5-moe-42b-a6.6b", "prefill_32k",
         multi_pod=False)
    _run("B2_flat_head_tp_serving", "phi3.5-moe-42b-a6.6b", "prefill_32k",
         multi_pod=False, rule_overrides={"embed": ()})
    _run("B3_tp_serving_cp_attn", "phi3.5-moe-42b-a6.6b", "prefill_32k",
         multi_pod=False,
         rule_overrides={"embed": (), "attn_q_seq": ("model",)})


def cell_c():
    from repro.config import SyncConfig
    print("== Cell C: qwen3-moe × train_4k (2×16×16, MSF ladder) ==")
    _run("C0_paper_msf1_everystep", "qwen3-moe-235b-a22b", "train_4k",
         multi_pod=True, sync=SyncConfig(strategy="sync_every_step"))
    _run("C1_paper_periodic_H8", "qwen3-moe-235b-a22b", "train_4k",
         multi_pod=True, sync=SyncConfig(strategy="hierarchical", period=8))
    _run("C2_periodic_H64", "qwen3-moe-235b-a22b", "train_4k",
         multi_pod=True, sync=SyncConfig(strategy="hierarchical", period=64))
    _run("C3_H8_int8", "qwen3-moe-235b-a22b", "train_4k",
         multi_pod=True,
         sync=SyncConfig(strategy="hierarchical", period=8,
                         compression="int8"))
    _run("C4_H8_int16", "qwen3-moe-235b-a22b", "train_4k",
         multi_pod=True,
         sync=SyncConfig(strategy="hierarchical", period=8,
                         compression="int16"))


if __name__ == "__main__":
    which = sys.argv[1:] or ["A", "B", "C"]
    if "A" in which:
        cell_a()
    if "B" in which:
        cell_b()
    if "C" in which:
        cell_c()
