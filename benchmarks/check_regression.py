"""Bench-regression gate: diff BENCH_*.json bundles against baselines.

CI's ``bench-gate`` job collects the ``BENCH_<sweep>.json`` bundles the
smoke runs produced (``benchmarks.run ... --json``) and compares them
against the committed baselines under ``experiments/bench/baseline/``:

* **time metrics** (simulated step/sync/wall times — deterministic: fixed
  seeds, pure numpy) fail on a > ``--tol`` (default 25%) regression;
* **acceptance metrics** split by how they are produced. Pure-numpy /
  analytic ones (comm-reduction factor, controller-vs-oracle error,
  async-decoupling ratio, wire bytes) are reproduced bit-for-bit by the
  same code, so *any* drop vs the baseline fails. Metrics that come out
  of jitted jax runs (gossip CV-accuracy parity, kernel max-abs-err) are
  gated against their *acceptance bounds* instead (accuracy within 0.5%
  of the global baseline; kernel error <= 1e-3) — XLA numerics shift
  across jax releases and machines, so a baseline-relative epsilon would
  fail on environment changes, not regressions;
* metrics measured on real hardware (kernel/sync wall micros) are
  reported but **not** gated: CI runners' absolute speed is not
  comparable to the machine that committed the baseline.

Refreshing baselines after an intentional change::

    PYTHONPATH=src python -m benchmarks.run simsync_sweep hinge_kernel \
        overlap_sweep gossip_sweep --json --out experiments/bench/baseline

then commit the updated ``experiments/bench/baseline/BENCH_*.json``.

Exit status: 0 = all gates pass, 1 = regression (or missing bundle).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Iterator, List, Optional, Tuple

# relative slack for "any drop" comparisons of the pure-numpy metrics:
# identical code on identical seeds reproduces these bit-for-bit; the
# epsilon only absorbs float printing noise
ACCEPT_EPS = 1e-6

# the acceptance bounds the jax-derived metrics are gated against
GOSSIP_ACC_PARITY = -0.005  # CV accuracy within 0.5% of topology="all"
HINGE_MAX_ABS_ERR = 1e-3  # hinge kernel vs reference, fp32

Metric = Tuple[str, float, str, Optional[float]]
# kinds: "time"     — lower is better, gated at --tol relative regression
#        "higher"   — acceptance, any drop vs baseline fails
#        "lower"    — acceptance, any rise vs baseline fails
#        "bound_ge" — acceptance, fails below the fixed threshold
#        "bound_le" — acceptance, fails above the fixed threshold
#        "info"     — reported only (measured wall clock etc.)


def _rows(bundle: dict, sweep: str) -> List[dict]:
    return bundle.get("records", {}).get(sweep, [])


def _metrics_simsync(bundle: dict) -> Iterator[Metric]:
    for r in _rows(bundle, "simsync_sweep"):
        sec = r.get("section")
        if sec == "comm":
            key = f"comm[{r['topology']}/{r['overlap']}/H={r['H']}]"
            yield key + ".wall_s", r["wall_s"], "time", None
            yield key + ".comm_exposed_s", r["comm_exposed_s"], "time", None
        elif sec == "comm_reduction":
            val = r["reduction_x"]
            yield "comm_reduction.reduction_x", val, "higher", None
        elif sec == "straggler":
            key = f"straggler[{r['topology']}].wall_s"
            yield key, r["wall_s"], "time", None
        elif sec == "async":
            key = f"async[{r['mode']}].clean_block_mean_s"
            yield key, r["clean_block_mean_s"], "time", None
        elif sec == "async_decoupling":
            val = r["async_clean_ratio"]
            yield "async_decoupling.async_clean_ratio", val, "lower", None
            val = r["sync_ring_clean_ratio"]
            yield "async_decoupling.sync_ring_clean_ratio", val, "info", None
        elif sec == "adaptive":
            key = f"adaptive[{r['profile']}].rel_err"
            yield key, r["rel_err"], "lower", None
        elif sec == "ladder":
            key = f"ladder[{r['profile']}].rung_err"
            yield key, r["rung_err"], "lower", None


def _metrics_adaptive_trainer(bundle: dict) -> Iterator[Metric]:
    for r in _rows(bundle, "adaptive_trainer"):
        sec = r.get("section")
        if sec == "trajectory":
            key = f"trajectory[{r['profile']}]"
            yield key + ".rung_err", r["rung_err"], "lower", None
            yield key + ".final_h", r["final_h"], "info", None
            yield key + ".switches", r["switches"], "info", None
        elif sec == "per_rung":
            key = f"per_rung[{r['profile']}/H={r['H']}].block_s"
            yield key, r["block_s"], "time", None
        elif sec == "comm_saved":
            key = f"comm_saved[{r['profile']}]"
            yield key + ".saved_x", r["saved_x"], "higher", None
            comm = r["adaptive_comm_exposed_s"]
            yield key + ".adaptive_comm_exposed_s", comm, "time", None


def _csv_info(bundle: dict, prefix: str) -> Iterator[Metric]:
    """Info metrics from a bundle's CSV lines (``name,label,key,value``).

    The measured sweeps run parts of themselves in a subprocess when the
    parent has too few devices (overlap_sweep entirely; gossip_sweep's
    timing section), so their structured records are registered in the
    *child* and the bundle carries only the CSV lines — parse those.
    Measured on the runner — reported, not gated.
    """
    for line in bundle.get("csv", []):
        parts = line.split(",")
        if len(parts) < 3 or not line.startswith(prefix):
            continue
        try:
            value = float(parts[-1])
        except ValueError:
            continue
        yield "/".join(parts[1:-1]), value, "info", None


def _metrics_hinge(bundle: dict) -> Iterator[Metric]:
    for r in _rows(bundle, "hinge_kernel_bench"):
        err = r["max_abs_err"]
        key = f"hinge.max_abs_err[{r['mode']}]"
        yield key, err, "bound_le", HINGE_MAX_ABS_ERR
        yield f"hinge.ref_us[{r['mode']}]", r["ref_us"], "info", None
        yield f"hinge.pallas_us[{r['mode']}]", r["pallas_us"], "info", None


def _metrics_gossip(bundle: dict) -> Iterator[Metric]:
    for r in _rows(bundle, "gossip_sweep"):
        if r.get("section") == "acc" and r.get("topology") != "all":
            mode = r["topology"] + ("_async" if r.get("gossip_async") else "")
            key = f"gossip_acc[{r['dataset']}/{mode}].delta_vs_all"
            delta = r["delta_vs_all_same_h"]
            yield key, delta, "bound_ge", GOSSIP_ACC_PARITY
        elif r.get("section") == "bytes":
            key = f"gossip_bytes[{r['topology']}/K={r['K']}]"
            yield key, r["bytes"], "lower", None
    # the timing section runs in a subprocess — only its CSV lines land
    # in this bundle
    yield from _csv_info(bundle, "gossip_sweep,sync_us,")


def _metrics_overlap(bundle: dict) -> Iterator[Metric]:
    # overlap_sweep re-executes itself in an 8-device subprocess on small
    # hosts (the CI case), so the bundle's records are empty — the CSV
    # lines are the only machine-readable output
    yield from _csv_info(bundle, "overlap_sweep,")


EXTRACTORS = {
    "BENCH_simsync_sweep.json": _metrics_simsync,
    "BENCH_adaptive_trainer.json": _metrics_adaptive_trainer,
    "BENCH_hinge_kernel.json": _metrics_hinge,
    "BENCH_gossip_sweep.json": _metrics_gossip,
    "BENCH_overlap_sweep.json": _metrics_overlap,
}


def _load(path: str) -> Optional[dict]:
    if not os.path.isfile(path):
        return None
    with open(path) as f:
        return json.load(f)


def _gate(kind: str, cv: float, bv: float, tol: float, thr) -> bool:
    if kind == "time":
        return cv <= bv * (1.0 + tol)
    if kind == "higher":
        return cv >= bv - ACCEPT_EPS * max(1.0, abs(bv))
    if kind == "lower":
        return cv <= bv + ACCEPT_EPS * max(1.0, abs(bv))
    if kind == "bound_ge":
        return cv >= thr
    if kind == "bound_le":
        return cv <= thr
    return True


def check_bundle(
    name: str, cur: dict, base: dict, tol: float, out: List[str]
) -> int:
    extract = EXTRACTORS.get(name)
    if extract is None:
        out.append(f"  ? {name}: no extractor registered — skipped")
        return 0
    cur_m = {k: (v, kind, thr) for k, v, kind, thr in extract(cur)}
    base_m = {k: (v, kind, thr) for k, v, kind, thr in extract(base)}
    failures = 0
    for key, (bv, kind, thr) in sorted(base_m.items()):
        if key not in cur_m:
            out.append(f"  FAIL {key}: missing from current run")
            failures += 1
            continue
        cv = cur_m[key][0]
        if kind == "info":
            out.append(f"  info       {key}: {cv:.6g} (base {bv:.6g})")
            continue
        ok = _gate(kind, cv, bv, tol, thr)
        verdict = "ok" if ok else f"FAIL {kind}"
        bounded = kind.startswith("bound")
        ref = f"bound {thr:.6g}" if bounded else f"base {bv:.6g}"
        out.append(f"  {verdict:14s} {key}: {cv:.6g} vs {ref}")
        failures += 0 if ok else 1
    for key in sorted(set(cur_m) - set(base_m)):
        cv = cur_m[key][0]
        out.append(f"  new            {key}: {cv:.6g} (no baseline yet)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.check_regression",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument(
        "--current",
        default="experiments/bench",
        help="directory with the fresh BENCH_*.json bundles",
    )
    ap.add_argument(
        "--baseline",
        default="experiments/bench/baseline",
        help="directory with the committed baselines",
    )
    ap.add_argument(
        "--tol",
        type=float,
        default=0.25,
        help="relative time-regression tolerance (default 25%%)",
    )
    args = ap.parse_args(argv)

    if not os.path.isdir(args.baseline):
        print(f"no baseline directory {args.baseline!r} — seed it with")
        print("  benchmarks.run ... --json --out", args.baseline)
        return 1
    names = sorted(os.listdir(args.baseline))
    names = [f for f in names if f.startswith("BENCH_")]
    names = [f for f in names if f.endswith(".json")]
    if not names:
        print(f"no BENCH_*.json baselines under {args.baseline!r}")
        return 1

    failures = 0
    for name in names:
        base = _load(os.path.join(args.baseline, name))
        cur = _load(os.path.join(args.current, name))
        if cur is None:
            print(f"{name}: FAIL — bundle missing from {args.current!r}")
            failures += 1
            continue
        out: List[str] = []
        n = check_bundle(name, cur, base, args.tol, out)
        failures += n
        print(f"{name}: {'FAIL' if n else 'ok'} ({n} regressions)")
        for line in out:
            print(line)
    print(f"bench-gate: {failures} failing metric(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
