"""Shared JSON record sink for benchmark sweeps.

Every sweep writes its structured rows here (``save``), which both
persists the per-sweep JSON under ``experiments/paper/`` (the historical
location the repo's BENCH artifacts live in) and registers the rows so
``benchmarks.run --json`` can bundle everything a sweep produced into one
uniform ``BENCH_<sweep>.json`` trajectory record (``take_saved``).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

OUT_DIR = "experiments/paper"

_LAST_SAVED: Dict[str, List[Dict]] = {}


def save(name: str, rows: List[Dict]) -> str:
    """Persist one sweep section's rows and register them for --json."""
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    _LAST_SAVED[name] = rows
    return path


def take_saved() -> Dict[str, List[Dict]]:
    """Drain the records registered since the last call (run.py --json)."""
    out = dict(_LAST_SAVED)
    _LAST_SAVED.clear()
    return out
