"""Paper-figure reproductions (one function per table/figure).

All figures run on synthetic stand-ins matched to the paper datasets'
(n, d, sparsity) — see repro.data.synthetic — scaled down where noted so
the whole suite finishes in minutes on CPU. Output: CSV rows on stdout +
JSON records under experiments/paper/.

  fig1_3   — CV accuracy vs block size (sequential SRDMS)      [Figs 1, 3]
  fig2_4   — training time vs block size (sequential)          [Figs 2, 4]
  fig5_9   — parallel vs sequential convergence (DMS≡SRDMS)    [Figs 5–9]
  fig10_15 — comm/compute time breakdown vs MSF × parallelism  [Figs 10–15]
  table2   — sequential vs parallel timing + accuracy          [Table II]
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import svm
from repro.data import make_svm_dataset

OUT_DIR = "experiments/paper"

# scaled-down sample counts (feature dims stay faithful — they set the
# communication volume, which is what the paper measures)
BENCH_N = {"ijcnn1": 8_000, "webspam": 12_000, "epsilon": 4_000}
EPOCHS = 12


def _ds(name):
    return make_svm_dataset(name, seed=0, n_override=BENCH_N[name])


def _save(name: str, rows: List[Dict]) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1)


def fig1_3() -> List[str]:
    """CV accuracy vs block size, sequential SRDMS (paper Figs 1 & 3)."""
    lines = []
    rows = []
    for dataset in ("ijcnn1", "webspam"):
        ds = _ds(dataset)
        x, y = jnp.asarray(ds.x_train), jnp.asarray(ds.y_train)
        xcv, ycv = jnp.asarray(ds.x_cv), jnp.asarray(ds.y_cv)
        w0 = jnp.zeros(ds.features)
        for bs in (1, 2, 4, 8, 512, 1024):
            w = svm.srdms(w0, x, y, epochs=EPOCHS, block_size=bs)
            acc = float(svm.accuracy(w, xcv, ycv))
            obj = float(svm.hinge_objective(w, x, y))
            rows.append({"dataset": dataset, "block": bs, "cv_acc": acc,
                         "objective": obj})
            lines.append(f"fig1_3,{dataset},block={bs},{acc:.4f}")
    _save("fig1_3_accuracy_vs_block", rows)
    return lines


def fig2_4() -> List[str]:
    """Training time vs block size, sequential (paper Figs 2 & 4)."""
    lines = []
    rows = []
    for dataset in ("ijcnn1", "webspam"):
        ds = _ds(dataset)
        x, y = jnp.asarray(ds.x_train), jnp.asarray(ds.y_train)
        xcv, ycv = jnp.asarray(ds.x_cv), jnp.asarray(ds.y_cv)
        w0 = jnp.zeros(ds.features)
        for bs in (1, 2, 4, 8, 512, 1024):
            # paper methodology (§V-C2): the CV-accuracy + objective
            # convergence check runs at EVERY model synchronization, so
            # high MSF (small blocks) pays it thousands of times per
            # epoch — the overhead whose dilution Figs 2/4 plot
            t0 = time.perf_counter()
            w, hist = svm.srdms(w0, x, y, epochs=EPOCHS, block_size=bs,
                                x_cv=xcv, y_cv=ycv, eval_every_sync=True)
            jax.block_until_ready(w)
            dt = time.perf_counter() - t0
            rows.append({"dataset": dataset, "block": bs, "train_s": dt})
            lines.append(f"fig2_4,{dataset},block={bs},{dt*1e6:.0f}")
    _save("fig2_4_time_vs_block", rows)
    return lines


def fig5_9() -> List[str]:
    """Parallel (DMS) vs sequential-replica convergence (Figs 5–9)."""
    lines = []
    rows = []
    for dataset in ("ijcnn1", "webspam"):
        ds = _ds(dataset)
        xcv, ycv = jnp.asarray(ds.x_cv), jnp.asarray(ds.y_cv)
        w0 = jnp.zeros(ds.features)
        for workers in (2, 8, 32):
            for bs in (1, 8, 512):
                w = svm.dms(w0, ds.x_train, ds.y_train, workers=workers,
                            epochs=EPOCHS, block_size=bs)
                acc = float(svm.accuracy(w, xcv, ycv))
                rows.append({"dataset": dataset, "workers": workers,
                             "block": bs, "cv_acc": acc})
                lines.append(
                    f"fig5_9,{dataset},K={workers} block={bs},{acc:.4f}")
    _save("fig5_9_parallel_convergence", rows)
    return lines


def fig10_15() -> List[str]:
    """Comm/compute breakdown vs MSF × parallelism (Figs 10–15).

    Paper methodology: instrument around the sync collective. We jit the
    per-block compute and the pmean sync separately (dms_timed_steps) on a
    real multi-device host mesh and time each. Run in a subprocess with 8
    host devices if this process has only 1.
    """
    n_dev = len(jax.devices())
    if n_dev < 8:
        import subprocess
        import sys
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.paper_figs", "fig10_15"],
            env=env, capture_output=True, text=True, timeout=1800)
        if out.returncode != 0:
            return [f"fig10_15,ERROR,,{out.stderr[-200:]}"]
        return [l for l in out.stdout.splitlines() if l.startswith("fig10_15")]

    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh((8,), ("data",))
    lines = []
    rows = []
    for dataset in ("ijcnn1", "webspam", "epsilon"):
        ds = _ds(dataset)
        k = 8
        n = (ds.n_train // k) * k
        xs = jnp.asarray(ds.x_train[:n].reshape(k, n // k, -1))
        ys = jnp.asarray(ds.y_train[:n].reshape(k, n // k))
        w0 = jnp.zeros(ds.features)
        for bs in (1, 8, 64, 512):
            if (n // k) // bs == 0:
                continue          # dataset too small for this block size
            with jax.set_mesh(mesh):
                compute, sync = svm.dms_timed_steps(mesh, "data",
                                                    block_size=bs)
                nb = (n // k) // bs
                xb = xs[:, :nb * bs].reshape(k, nb, bs, -1)
                yb = ys[:, :nb * bs].reshape(k, nb, bs)
                alpha = jnp.float32(0.5)
                # warmup
                wl = compute(w0, xb[:, 0], yb[:, 0], alpha)
                jax.block_until_ready(sync(wl))
                t_comp = t_sync = 0.0
                blocks = min(nb, 200)
                for i in range(blocks):
                    t0 = time.perf_counter()
                    wl = compute(w0, xb[:, i], yb[:, i], alpha)
                    jax.block_until_ready(wl)
                    t_comp += time.perf_counter() - t0
                    t0 = time.perf_counter()
                    w = sync(wl)
                    jax.block_until_ready(w)
                    t_sync += time.perf_counter() - t0
                # scale to a full epoch's block count
                scale = nb / blocks
                rows.append({"dataset": dataset, "workers": k, "block": bs,
                             "compute_s": t_comp * scale,
                             "comm_s": t_sync * scale,
                             "comm_frac": t_sync / (t_comp + t_sync)})
                lines.append(
                    f"fig10_15,{dataset},K={k} block={bs},"
                    f"comm_frac={t_sync/(t_comp+t_sync):.3f}")
    _save("fig10_15_comm_breakdown", rows)
    return lines


def table2() -> List[str]:
    """Sequential vs parallel timing + accuracy (Table II)."""
    lines = []
    rows = []
    for dataset in ("ijcnn1", "webspam"):
        ds = _ds(dataset)
        x, y = jnp.asarray(ds.x_train), jnp.asarray(ds.y_train)
        xt, yt = jnp.asarray(ds.x_test), jnp.asarray(ds.y_test)
        w0 = jnp.zeros(ds.features)

        t0 = time.perf_counter()
        w_seq = svm.seq_sgd(w0, x, y, epochs=EPOCHS)
        jax.block_until_ready(w_seq)
        t_seq = time.perf_counter() - t0
        acc_seq = float(svm.accuracy(w_seq, xt, yt))

        t0 = time.perf_counter()
        w_par = svm.dms(w0, ds.x_train, ds.y_train, workers=32,
                        epochs=EPOCHS, block_size=64)
        jax.block_until_ready(w_par)
        t_par = time.perf_counter() - t0
        acc_par = float(svm.accuracy(w_par, xt, yt))

        rows.append({"dataset": dataset, "seq_s": t_seq, "par_s": t_par,
                     "seq_acc": acc_seq, "par_acc": acc_par,
                     "speedup": t_seq / t_par})
        lines.append(f"table2,{dataset},speedup={t_seq/t_par:.1f}x,"
                     f"seq_acc={acc_seq:.4f} par_acc={acc_par:.4f}")
    _save("table2_speedup", rows)
    return lines


ALL = {"fig1_3": fig1_3, "fig2_4": fig2_4, "fig5_9": fig5_9,
       "fig10_15": fig10_15, "table2": table2}


if __name__ == "__main__":
    import sys
    which = sys.argv[1:] or list(ALL)
    for name in which:
        for line in ALL[name]():
            print(line)
