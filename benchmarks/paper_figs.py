"""Paper-figure reproductions (one function per table/figure).

All figures run on synthetic stand-ins matched to the paper datasets'
(n, d, sparsity) — see repro.data.synthetic — scaled down where noted so
the whole suite finishes in minutes on CPU. Output: CSV rows on stdout +
JSON records under experiments/paper/.

  fig1_3   — CV accuracy vs block size (sequential SRDMS)      [Figs 1, 3]
  fig2_4   — training time vs block size (sequential)          [Figs 2, 4]
  fig5_9   — parallel vs sequential convergence (DMS≡SRDMS)    [Figs 5–9]
  fig10_15 — comm/compute time breakdown vs MSF × parallelism  [Figs 10–15]
  table2   — sequential vs parallel timing + accuracy          [Table II]

Beyond-paper perf sections:

  overlap_sweep — blocking vs delayed vs chunked sync step time across the
                  H ladder (the overlap-aware sync engine's claim)
  gossip_sweep  — ring/pairwise gossip vs global all-reduce: O(1) neighbor
                  wire bytes vs 2P(K−1)/K, accuracy parity at the
                  autotuned (spectral-gap-capped) H, measured sync time
  hinge_kernel  — fused Pallas hinge block-gradient vs the jnp reference
"""
from __future__ import annotations

import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import record
from repro.core import svm
from repro.data import make_svm_dataset

OUT_DIR = record.OUT_DIR

# scaled-down sample counts (feature dims stay faithful — they set the
# communication volume, which is what the paper measures)
BENCH_N = {"ijcnn1": 8_000, "webspam": 12_000, "epsilon": 4_000}
EPOCHS = 12


def _ds(name):
    return make_svm_dataset(name, seed=0, n_override=BENCH_N[name])


def _save(name: str, rows: List[Dict]) -> None:
    record.save(name, rows)


def fig1_3() -> List[str]:
    """CV accuracy vs block size, sequential SRDMS (paper Figs 1 & 3)."""
    lines = []
    rows = []
    for dataset in ("ijcnn1", "webspam"):
        ds = _ds(dataset)
        x, y = jnp.asarray(ds.x_train), jnp.asarray(ds.y_train)
        xcv, ycv = jnp.asarray(ds.x_cv), jnp.asarray(ds.y_cv)
        w0 = jnp.zeros(ds.features)
        for bs in (1, 2, 4, 8, 512, 1024):
            w = svm.srdms(w0, x, y, epochs=EPOCHS, block_size=bs)
            acc = float(svm.accuracy(w, xcv, ycv))
            obj = float(svm.hinge_objective(w, x, y))
            rows.append({"dataset": dataset, "block": bs, "cv_acc": acc,
                         "objective": obj})
            lines.append(f"fig1_3,{dataset},block={bs},{acc:.4f}")
    _save("fig1_3_accuracy_vs_block", rows)
    return lines


def fig2_4() -> List[str]:
    """Training time vs block size, sequential (paper Figs 2 & 4)."""
    lines = []
    rows = []
    for dataset in ("ijcnn1", "webspam"):
        ds = _ds(dataset)
        x, y = jnp.asarray(ds.x_train), jnp.asarray(ds.y_train)
        xcv, ycv = jnp.asarray(ds.x_cv), jnp.asarray(ds.y_cv)
        w0 = jnp.zeros(ds.features)
        for bs in (1, 2, 4, 8, 512, 1024):
            # paper methodology (§V-C2): the CV-accuracy + objective
            # convergence check runs at EVERY model synchronization, so
            # high MSF (small blocks) pays it thousands of times per
            # epoch — the overhead whose dilution Figs 2/4 plot
            t0 = time.perf_counter()
            w, hist = svm.srdms(w0, x, y, epochs=EPOCHS, block_size=bs,
                                x_cv=xcv, y_cv=ycv, eval_every_sync=True)
            jax.block_until_ready(w)
            dt = time.perf_counter() - t0
            rows.append({"dataset": dataset, "block": bs, "train_s": dt})
            lines.append(f"fig2_4,{dataset},block={bs},{dt*1e6:.0f}")
    _save("fig2_4_time_vs_block", rows)
    return lines


def fig5_9() -> List[str]:
    """Parallel (DMS) vs sequential-replica convergence (Figs 5–9)."""
    lines = []
    rows = []
    for dataset in ("ijcnn1", "webspam"):
        ds = _ds(dataset)
        xcv, ycv = jnp.asarray(ds.x_cv), jnp.asarray(ds.y_cv)
        w0 = jnp.zeros(ds.features)
        for workers in (2, 8, 32):
            for bs in (1, 8, 512):
                w = svm.dms(w0, ds.x_train, ds.y_train, workers=workers,
                            epochs=EPOCHS, block_size=bs)
                acc = float(svm.accuracy(w, xcv, ycv))
                rows.append({"dataset": dataset, "workers": workers,
                             "block": bs, "cv_acc": acc})
                lines.append(
                    f"fig5_9,{dataset},K={workers} block={bs},{acc:.4f}")
    _save("fig5_9_parallel_convergence", rows)
    return lines


def fig10_15() -> List[str]:
    """Comm/compute breakdown vs MSF × parallelism (Figs 10–15).

    Paper methodology: instrument around the sync collective. We jit the
    per-block compute and the pmean sync separately (dms_timed_steps) on a
    real multi-device host mesh and time each. Run in a subprocess with 8
    host devices if this process has only 1.
    """
    n_dev = len(jax.devices())
    if n_dev < 8:
        import subprocess
        import sys
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["JAX_PLATFORMS"] = "cpu"   # the flag only fakes CPU devices
        env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.paper_figs", "fig10_15"],
            env=env, capture_output=True, text=True, timeout=1800)
        if out.returncode != 0:
            return [f"fig10_15,ERROR,,{out.stderr[-200:]}"]
        return [l for l in out.stdout.splitlines() if l.startswith("fig10_15")]

    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh((8,), ("data",))
    lines = []
    rows = []
    for dataset in ("ijcnn1", "webspam", "epsilon"):
        ds = _ds(dataset)
        k = 8
        n = (ds.n_train // k) * k
        xs = jnp.asarray(ds.x_train[:n].reshape(k, n // k, -1))
        ys = jnp.asarray(ds.y_train[:n].reshape(k, n // k))
        w0 = jnp.zeros(ds.features)
        for bs in (1, 8, 64, 512):
            if (n // k) // bs == 0:
                continue          # dataset too small for this block size
            with jax.set_mesh(mesh):
                compute, sync = svm.dms_timed_steps(mesh, "data",
                                                    block_size=bs)
                nb = (n // k) // bs
                xb = xs[:, :nb * bs].reshape(k, nb, bs, -1)
                yb = ys[:, :nb * bs].reshape(k, nb, bs)
                alpha = jnp.float32(0.5)
                # warmup
                wl = compute(w0, xb[:, 0], yb[:, 0], alpha)
                jax.block_until_ready(sync(wl))
                t_comp = t_sync = 0.0
                blocks = min(nb, 200)
                for i in range(blocks):
                    t0 = time.perf_counter()
                    wl = compute(w0, xb[:, i], yb[:, i], alpha)
                    jax.block_until_ready(wl)
                    t_comp += time.perf_counter() - t0
                    t0 = time.perf_counter()
                    w = sync(wl)
                    jax.block_until_ready(w)
                    t_sync += time.perf_counter() - t0
                # scale to a full epoch's block count
                scale = nb / blocks
                rows.append({"dataset": dataset, "workers": k, "block": bs,
                             "compute_s": t_comp * scale,
                             "comm_s": t_sync * scale,
                             "comm_frac": t_sync / (t_comp + t_sync)})
                lines.append(
                    f"fig10_15,{dataset},K={k} block={bs},"
                    f"comm_frac={t_sync/(t_comp+t_sync):.3f}")
    _save("fig10_15_comm_breakdown", rows)
    return lines


def table2() -> List[str]:
    """Sequential vs parallel timing + accuracy (Table II)."""
    lines = []
    rows = []
    for dataset in ("ijcnn1", "webspam"):
        ds = _ds(dataset)
        x, y = jnp.asarray(ds.x_train), jnp.asarray(ds.y_train)
        xt, yt = jnp.asarray(ds.x_test), jnp.asarray(ds.y_test)
        w0 = jnp.zeros(ds.features)

        t0 = time.perf_counter()
        w_seq = svm.seq_sgd(w0, x, y, epochs=EPOCHS)
        jax.block_until_ready(w_seq)
        t_seq = time.perf_counter() - t0
        acc_seq = float(svm.accuracy(w_seq, xt, yt))

        t0 = time.perf_counter()
        w_par = svm.dms(w0, ds.x_train, ds.y_train, workers=32,
                        epochs=EPOCHS, block_size=64)
        jax.block_until_ready(w_par)
        t_par = time.perf_counter() - t0
        acc_par = float(svm.accuracy(w_par, xt, yt))

        rows.append({"dataset": dataset, "seq_s": t_seq, "par_s": t_par,
                     "seq_acc": acc_seq, "par_acc": acc_par,
                     "speedup": t_seq / t_par})
        lines.append(f"table2,{dataset},speedup={t_seq/t_par:.1f}x,"
                     f"seq_acc={acc_seq:.4f} par_acc={acc_par:.4f}")
    _save("table2_speedup", rows)
    return lines


def overlap_sweep() -> List[str]:
    """Blocking vs delayed vs chunked sync per-step time across the H ladder.

    The overlap engine's claim (ISSUE 1): delayed/chunked step time ≤
    blocking at every H. Times a jitted scan of dms_block_stepper blocks on
    the synthetic Epsilon stand-in (d=2000 — the sync-bytes-heavy dataset),
    8 workers, min over repeats. Run in a subprocess with 8 host devices if
    this process has only 1.
    """
    n_dev = len(jax.devices())
    if n_dev < 8:
        import subprocess
        import sys
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        # pin the child to CPU: the flag only fakes CPU devices, so a child
        # on a 1-7 GPU host would still see <8 devices and recurse
        env["JAX_PLATFORMS"] = "cpu"
        env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.paper_figs", "overlap_sweep"],
            env=env, capture_output=True, text=True, timeout=1800)
        if out.returncode != 0:
            return [f"overlap_sweep,ERROR,,{out.stderr[-200:]}"]
        return [l for l in out.stdout.splitlines()
                if l.startswith("overlap_sweep")]

    from repro.launch.mesh import make_test_mesh
    from repro.core import svm as svm_mod
    mesh = make_test_mesh((8,), ("data",))
    k = 8
    chunks = 4        # shard count for overlap="chunked" (measured + model)
    rng = np.random.default_rng(0)
    # (label, x (K, n_local, d), y): epsilon is the paper's byte-heavy
    # dataset; "wide64k" makes the sync wire bytes dominate even on host
    # fabrics (d=65536 ⇒ 256 KiB per fp32 sync) so the chunked byte saving
    # is visible where epsilon's d=2000 sync is latency-bound.
    workloads = []
    ds = make_svm_dataset("epsilon", seed=0, n_override=16_384)
    n = (ds.n_train // k) * k
    workloads.append((
        "epsilon", (1, 8, 64, 512),
        jnp.asarray(ds.x_train[:n].reshape(k, n // k, ds.features)),
        jnp.asarray(ds.y_train[:n].reshape(k, n // k))))
    dw, nlw = 65_536, 256
    workloads.append((
        "wide64k", (1, 8, 64),
        jnp.asarray(rng.normal(size=(k, nlw, dw)) / np.sqrt(dw), jnp.float32),
        jnp.asarray(np.where(rng.random((k, nlw)) > 0.5, 1.0, -1.0),
                    jnp.float32)))

    lines, rows = [], []
    with jax.set_mesh(mesh):
        for label, ladder, xs, ys in workloads:
            _, n_local, d = xs.shape
            w0 = jnp.zeros(d)
            alpha = jnp.float32(0.5)
            for h in ladder:
                nb = min(n_local // h, 256)
                if nb == 0:
                    continue
                xb = jnp.swapaxes(
                    xs[:, : nb * h].reshape(k, nb, h, d), 0, 1)  # (nb,K,h,d)
                yb = jnp.swapaxes(ys[:, : nb * h].reshape(k, nb, h), 0, 1)
                runs = {}
                for mode in ("none", "delayed", "chunked"):
                    step = svm_mod.dms_block_stepper(mesh, "data", d=d,
                                                     overlap=mode,
                                                     chunks=chunks)
                    carry0 = svm_mod.dms_stepper_init(w0, k, overlap=mode,
                                                      chunks=chunks)

                    def make_run(step=step, alpha=alpha):
                        @jax.jit
                        def run(carry, xb, yb):
                            def body(c, xy):
                                return step(c, xy[0], xy[1], alpha), None
                            return jax.lax.scan(body, carry, (xb, yb))[0]
                        return run
                    runs[mode] = (make_run(), carry0)
                    jax.block_until_ready(runs[mode][0](carry0, xb, yb))

                # interleave repeats across modes so machine-load drift hits
                # every mode equally; report the min
                best = {mode: float("inf") for mode in runs}
                for _ in range(6):
                    for mode, (run, carry0) in runs.items():
                        t0 = time.perf_counter()
                        jax.block_until_ready(run(carry0, xb, yb))
                        best[mode] = min(best[mode],
                                         time.perf_counter() - t0)
                step_us = {m: b / (nb * h) * 1e6 for m, b in best.items()}
                for mode in ("none", "delayed", "chunked"):
                    lines.append(f"overlap_sweep,{label},H={h} mode={mode},"
                                 f"{step_us[mode]:.2f}")
                rows.append({"dataset": label, "workers": k, "H": h,
                             "blocks": nb, "step_us": step_us})

            # critical-path model rows (mode=model-*): the cost model fed
            # with the measured T_step / T_sync of this workload. On an
            # oversubscribed host CPU the runtime serializes collectives
            # with compute (no true overlap, and barrier latency ≫ wire
            # time), so the measured rows show parity; the model rows show
            # the schedule-level effect the delayed/chunked modes buy on a
            # fabric that can overlap (see also the jaxpr dependency test).
            from repro.config import SyncConfig
            from repro.core import costmodel
            meas = {r["H"]: r["step_us"] for r in rows
                    if r["dataset"] == label}
            if len(meas) >= 2:
                h_max = max(meas)
                t_step = meas[h_max]["none"]
                t_sync = max(0.0, (meas[min(meas)]["none"] - t_step)
                             * min(meas))
                for h in sorted(meas):
                    for mode in ("none", "delayed", "chunked"):
                        t_s = t_sync / (chunks if mode == "chunked" else 1)
                        val = costmodel.overlapped_step_time(
                            t_step, t_s, h, SyncConfig(overlap=mode))
                        lines.append(f"overlap_sweep,{label},"
                                     f"H={h} mode=model-{mode},{val:.2f}")
    _save("overlap_sweep_step_time", rows)
    return lines


def gossip_sweep() -> List[str]:
    """Gossip (ring/pairwise) vs global all-reduce sync — ISSUE 2's claims.

    Section 1 (``bytes`` rows): analytic per-chip wire bytes of one sync
    from the shared cost model across the replica-count ladder. The
    all-reduce moves ``2P(K−1)/K`` (growing toward 2P and paying a global
    barrier); ``ring`` moves a constant ``2P`` to its two neighbors —
    O(1) in K — and ``pairwise`` a constant ``1P``.

    Section 2 (``acc`` rows): accuracy parity on the paper datasets at the
    *autotuner-chosen* H per topology. The tuner's spectral-gap guardrail
    caps gossip H tighter (ring mixes only ``1−λ₂`` per round), which is
    exactly what keeps the gossip accuracy within 0.5% of the global
    baseline. TuneInputs model a slow fabric (comm-bound) so the drift cap
    is the binding constraint — the regime where the guardrail matters.

    Section 3 (``sync_us`` rows): measured per-sync wall time of the
    blocking exchange (dms_timed_steps) on an 8-worker host mesh — the
    gossip exchange does not pay the global barrier. Run in a subprocess
    with 8 host devices if this process has only 1.
    """
    from repro.config import SyncConfig
    from repro.core import costmodel
    from repro.core.autotune import TuneInputs, choose_period

    lines, rows = [], []

    # --- 1) analytic wire bytes vs K -----------------------------------
    p_bytes = 2000 * 4          # epsilon's fp32 weight vector, per chip
    for topo in ("all", "ring", "pairwise"):
        for k in (2, 4, 8, 16, 32, 64):
            cfg = SyncConfig(strategy="periodic", topology=topo)
            b = costmodel.wire_bytes_per_sync(p_bytes, k, cfg)
            rows.append({"section": "bytes", "topology": topo, "K": k,
                         "bytes": b})
            lines.append(f"gossip_sweep,bytes,K={k} topo={topo},{b:.0f}")

    # --- 2) accuracy parity at the autotuned H -------------------------
    # For each gossip topology: train at ITS autotuner-chosen H (the
    # spectral-gap guardrail picks a smaller H for sparser mixing) and
    # compare against topology="all" at the SAME H — isolating what the
    # inexact neighbor averaging costs from the paper's own H effect.
    for dataset in ("ijcnn1", "webspam"):
        ds = _ds(dataset)
        k = 8
        xcv, ycv = jnp.asarray(ds.x_cv), jnp.asarray(ds.y_cv)
        w0 = jnp.zeros(ds.features)
        # comm-bound fabric so the spectral-gap drift cap binds: per-step
        # drift 1e-3 ⇒ blocking cap 50 at max_drift=0.05, gossip tighter
        inp = TuneInputs(param_bytes_per_chip=ds.features * 4, replicas=k,
                         step_time_s=1e-6, link_bw=1e6,
                         grad_norm=1.0, param_norm=1.0, lr=1e-3)

        acc_cache = {}

        def acc_at(topo, h, gossip_async=False):
            # memoized: the topology="all" reference at a given H is
            # retrained once, not once per gossip row that shares the H
            key = (topo, h, gossip_async)
            if key not in acc_cache:
                w = svm.dms(w0, ds.x_train, ds.y_train, workers=k,
                            epochs=EPOCHS, block_size=h, topology=topo,
                            gossip_async=gossip_async)
                acc_cache[key] = float(svm.accuracy(w, xcv, ycv))
            return acc_cache[key]

        # async-vs-sync comparison rows: each gossip topology also trains
        # with the unsynchronized-round exchange at ITS tuned H (the
        # staleness-aware spectral-gap cap picks a smaller H), compared
        # against topology="all" at the same H
        for topo, gossip_async in (("all", False), ("ring", False),
                                   ("ring", True), ("pairwise", False),
                                   ("pairwise", True)):
            cfg = SyncConfig(strategy="periodic", topology=topo,
                             gossip_async=gossip_async)
            h = choose_period(inp, cfg, target_overhead=0.05, max_drift=0.05)
            acc = acc_at(topo, h, gossip_async)
            acc_ref = acc if topo == "all" else acc_at("all", h)
            mode = f"{topo}{'_async' if gossip_async else ''}"
            rows.append({"section": "acc", "dataset": dataset,
                         "topology": topo, "gossip_async": gossip_async,
                         "H": h, "cv_acc": acc,
                         "spectral_gap": costmodel.effective_spectral_gap(
                             k, topo, staleness=1 if gossip_async else 0)
                         if topo != "all"
                         else costmodel.spectral_gap(k, topo),
                         "delta_vs_all_same_h": acc - acc_ref})
            lines.append(f"gossip_sweep,acc,{dataset} topo={mode} H={h},"
                         f"{acc:.4f} (Δ@H={acc - acc_ref:+.4f})")

    # --- 3) measured per-sync time on a host mesh ----------------------
    n_dev = len(jax.devices())
    if n_dev < 8:
        import subprocess
        import sys
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["JAX_PLATFORMS"] = "cpu"   # the flag only fakes CPU devices
        env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.paper_figs",
             "gossip_sweep_timing"],
            env=env, capture_output=True, text=True, timeout=1800)
        if out.returncode != 0:
            lines.append(f"gossip_sweep,ERROR,,{out.stderr[-200:]}")
        else:
            lines += [l for l in out.stdout.splitlines()
                      if l.startswith("gossip_sweep")]
        _save("gossip_sweep", rows)
        return lines

    lines += gossip_sweep_timing()
    _save("gossip_sweep", rows)
    return lines


def gossip_sweep_timing() -> List[str]:
    """Measured blocking-sync wall time per topology (8 host workers)."""
    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh((8,), ("data",))
    k, d = 8, 65_536      # wide model: sync bytes dominate barrier latency
    rng = np.random.default_rng(0)
    w_locals = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
    cnt = jnp.zeros((), jnp.int32)
    lines, rows = [], []
    with jax.set_mesh(mesh):
        for topo, gossip_async in (("all", False), ("ring", False),
                                   ("ring", True), ("pairwise", False),
                                   ("pairwise", True)):
            _, sync = svm.dms_timed_steps(mesh, "data", block_size=8,
                                          topology=topo,
                                          gossip_async=gossip_async)
            if gossip_async:
                sent, mixbuf = svm.dms_async_buffers_init(w_locals, topo)
                run = lambda: sync(w_locals, sent, mixbuf, cnt)
            elif topo == "all":
                run = lambda: sync(w_locals)
            else:
                run = lambda: sync(w_locals, cnt)
            jax.block_until_ready(run())
            best = float("inf")
            for _ in range(20):
                t0 = time.perf_counter()
                jax.block_until_ready(run())
                best = min(best, time.perf_counter() - t0)
            mode = f"{topo}{'_async' if gossip_async else ''}"
            rows.append({"section": "sync_us", "topology": topo,
                         "gossip_async": gossip_async,
                         "K": k, "d": d, "sync_us": best * 1e6})
            lines.append(f"gossip_sweep,sync_us,K={k} topo={mode},"
                         f"{best*1e6:.1f}")
    _save("gossip_sweep_timing", rows)
    return lines


def hinge_kernel() -> List[str]:
    """Fused Pallas hinge block-gradient vs the jnp reference (hot path).

    With the interpret default fixed (auto: compiled on TPU/GPU, interpreter
    only on CPU) this times the compiled kernel on accelerators; on CPU the
    interpreter is orders slower, so the problem is shrunk to keep the
    suite fast and the row is labeled ``interpret``.
    """
    from repro.core.svm import block_grad
    from repro.kernels.hinge import ops as hinge_ops
    interp = hinge_ops.default_interpret()
    n, d = (256, 128) if interp else (4096, 2048)
    reps = 3 if interp else 20
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(np.where(rng.random(n) > 0.5, 1.0, -1.0), jnp.float32)
    w = jnp.asarray(rng.normal(size=d), jnp.float32)

    g_ref = block_grad(w, x, y, 1.0, "jnp")
    g_pal = hinge_ops.hinge_block_grad(w, x, y, 1.0)
    err = float(jnp.max(jnp.abs(g_ref - g_pal)))
    assert err < 1e-3, err

    def best_of(fn):
        jax.block_until_ready(fn())
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best * 1e6

    mode = "interpret" if interp else "compiled"
    t_ref = best_of(lambda: block_grad(w, x, y, 1.0, "jnp"))
    t_pal = best_of(lambda: hinge_ops.hinge_block_grad(w, x, y, 1.0))
    rows = [{"mode": mode, "n": n, "d": d, "ref_us": t_ref,
             "pallas_us": t_pal, "max_abs_err": err}]
    _save("hinge_kernel_bench", rows)
    return [f"hinge_kernel,ref,n={n} d={d},{t_ref:.1f}",
            f"hinge_kernel,pallas-{mode},n={n} d={d},{t_pal:.1f}"]


ALL = {"fig1_3": fig1_3, "fig2_4": fig2_4, "fig5_9": fig5_9,
       "fig10_15": fig10_15, "table2": table2,
       "overlap_sweep": overlap_sweep, "gossip_sweep": gossip_sweep,
       "gossip_sweep_timing": gossip_sweep_timing,
       "hinge_kernel": hinge_kernel}


if __name__ == "__main__":
    import sys
    which = sys.argv[1:] or list(ALL)
    for name in which:
        for line in ALL[name]():
            print(line)
