"""Assemble the §Dry-run / §Roofline tables from experiments/dryrun JSONs."""
from __future__ import annotations

import json
import os
from glob import glob
from typing import Dict, List

ARCH_ORDER = ["phi3.5-moe-42b-a6.6b", "qwen3-moe-235b-a22b", "llama3.2-3b",
              "internlm2-1.8b", "smollm-360m", "qwen2.5-3b", "whisper-base",
              "mamba2-2.7b", "zamba2-1.2b", "paligemma-3b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirname: str = "experiments/dryrun") -> List[Dict]:
    recs = [json.load(open(f)) for f in glob(os.path.join(dirname, "*.json"))]
    recs.sort(key=lambda r: (r["mesh"], ARCH_ORDER.index(r["arch"]),
                             SHAPE_ORDER.index(r["shape"])))
    return recs


def markdown_table(recs: List[Dict], mesh: str = "16x16") -> str:
    head = ("| arch | shape | GB/dev | fits | compute_s | memory_s | "
            "collective_s | bound | MODEL/HLO | MFU-bound |\n"
            "|---|---|---|---|---|---|---|---|---|---|")
    rows = [head]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                        f"| SKIP | — | — |")
            continue
        t = r["roofline"]
        h = max(1, r.get("opt_steps_per_call", 1))   # per optimizer step
        rows.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['resident_bytes_per_device']/1e9:.1f} "
            f"| {'✓' if r['fits_16g'] else '✗'} "
            f"| {t['compute_s']/h:.3f} | {t['memory_s']/h:.3f} "
            f"| {t['collective_s']/h:.3f} | {t['dominant']} "
            f"| {t['useful_ratio']*h:.2f} | {t['mfu_bound']*h*100:.1f}% |")
    return "\n".join(rows)


def csv_lines(recs: List[Dict]) -> List[str]:
    out = []
    for r in recs:
        if r["status"] != "ok":
            continue
        t = r["roofline"]
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        out.append(f"roofline,{r['arch']}|{r['shape']}|{r['mesh']},"
                   f"{bound*1e6:.0f},"
                   f"bound={t['dominant']} mfu_bound={t['mfu_bound']:.3f}")
    return out


if __name__ == "__main__":
    recs = load()
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### mesh {mesh}\n")
        print(markdown_table(recs, mesh))
