"""Batched serving demo: prefill + decode with KV cache on any arch.

    PYTHONPATH=src python examples/serve_batched.py [--arch smollm-360m]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_smoke
from repro.launch.mesh import make_test_mesh, test_mesh_config
from repro.launch.serve import ServeEngine


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-360m")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--gen-tokens", type=int, default=12)
    args = p.parse_args()

    cfg = get_smoke(args.arch)
    n_dev = len(jax.devices())
    mesh = make_test_mesh((n_dev, 1))
    mesh_cfg = test_mesh_config((n_dev, 1))

    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab_size,
                           size=(args.requests, args.prompt_len),
                           dtype=np.int32)
    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = jnp.zeros(
            (args.requests, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        extras["frames"] = jnp.zeros(
            (args.requests, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)

    engine = ServeEngine(cfg, mesh, mesh_cfg,
                         max_len=args.prompt_len + args.gen_tokens
                         + (cfg.num_image_tokens or 0) + 1)
    t0 = time.time()
    tokens = engine.generate(prompts, args.gen_tokens, extras=extras)
    dt = time.time() - t0
    print(f"arch={cfg.name} requests={args.requests} "
          f"generated={tokens.shape[1]} tok/req "
          f"({tokens.size / dt:.1f} tok/s)")
    for i, row in enumerate(tokens[:4]):
        print(f"  req{i}: {row.tolist()}")


if __name__ == "__main__":
    main()
