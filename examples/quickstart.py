"""Quickstart: the paper's finding in 60 seconds on a laptop CPU.

Trains the paper's SGD-SVM on a synthetic Ijcnn1 stand-in at three model
synchronization frequencies (MSF = block size) and shows what the paper
shows: accuracy is flat across MSF while the sync count — the
communication driver — drops by orders of magnitude.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax.numpy as jnp

from repro.core import svm
from repro.data import make_svm_dataset


def main() -> None:
    ds = make_svm_dataset("ijcnn1", n_override=8000)
    x, y = jnp.asarray(ds.x_train), jnp.asarray(ds.y_train)
    xcv, ycv = jnp.asarray(ds.x_cv), jnp.asarray(ds.y_cv)
    w0 = jnp.zeros(ds.features)
    epochs, workers = 12, 8

    print(f"dataset: ijcnn1 stand-in (n={ds.n_train}, d={ds.features})")
    print(f"DMS: {workers} workers × {epochs} epochs\n")
    print(f"{'block (1/MSF)':>14} {'syncs/epoch':>12} {'cv acc':>8} "
          f"{'wall s':>8}")
    for block in (1, 16, 256):
        syncs = ds.n_train // workers // block
        t0 = time.perf_counter()
        w = svm.dms(w0, ds.x_train, ds.y_train, workers=workers,
                    epochs=epochs, block_size=block)
        acc = float(svm.accuracy(w, xcv, ycv))
        dt = time.perf_counter() - t0
        print(f"{block:>14} {syncs:>12} {acc:>8.4f} {dt:>8.2f}")

    print("\npaper's conclusion: lower the MSF (bigger blocks) — same "
          "accuracy, a fraction of the communication.")


if __name__ == "__main__":
    main()
