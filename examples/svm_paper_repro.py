"""Full reproduction of the paper's experimental arc on one machine.

Runs, for each paper dataset stand-in:
  1. sequential baseline (Algorithm 1),
  2. the sequential replica sweep over block sizes (Algorithm 2, Figs 1–4),
  3. distributed DMS at parallelism 2/8/32 (Algorithm 3, Figs 5–9 + Table II),
and prints the speedup/accuracy summary in the paper's Table II format.

    PYTHONPATH=src python examples/svm_paper_repro.py [--quick]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import svm
from repro.data import make_svm_dataset


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true")
    args = p.parse_args()
    n_map = ({"ijcnn1": 4000, "webspam": 6000} if args.quick
             else {"ijcnn1": 12000, "webspam": 30000, "epsilon": 6000})
    epochs = 8 if args.quick else 15

    print("| dataset | seq s | par s (K=32) | seq acc | par acc | speedup |")
    print("|---|---|---|---|---|---|")
    for name, n in n_map.items():
        ds = make_svm_dataset(name, n_override=n)
        x, y = jnp.asarray(ds.x_train), jnp.asarray(ds.y_train)
        xt, yt = jnp.asarray(ds.x_test), jnp.asarray(ds.y_test)
        w0 = jnp.zeros(ds.features)

        t0 = time.perf_counter()
        w_seq = svm.seq_sgd(w0, x, y, epochs=epochs)
        jax.block_until_ready(w_seq)
        t_seq = time.perf_counter() - t0

        t0 = time.perf_counter()
        w_par = svm.dms(w0, ds.x_train, ds.y_train, workers=32,
                        epochs=epochs, block_size=64)
        jax.block_until_ready(w_par)
        t_par = time.perf_counter() - t0

        print(f"| {name} | {t_seq:.2f} | {t_par:.2f} "
              f"| {float(svm.accuracy(w_seq, xt, yt)):.4f} "
              f"| {float(svm.accuracy(w_par, xt, yt)):.4f} "
              f"| {t_seq / t_par:.1f}× |")

        # block-size sweep (Figs 1–4 analog)
        for bs in (1, 8, 512):
            w = svm.srdms(w0, x, y, epochs=epochs, block_size=bs)
            acc = float(svm.accuracy(w, jnp.asarray(ds.x_cv),
                                     jnp.asarray(ds.y_cv)))
            print(f"    block={bs:<4d} cv_acc={acc:.4f}")


if __name__ == "__main__":
    main()
