"""End-to-end LM training with the paper's MSF schedule (local SGD).

Trains a reduced llama-family model with the full production stack —
config system, mesh, sync engine, data pipeline, checkpointing, FT runner —
comparing every-step sync (paper's MSF=1) against periodic sync (H=4).
On this CPU container it runs a ~5M-param model for 40 blocks; the same
script drives the real thing with ``--arch llama3.2-3b`` (no --reduced) on
a pod.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/lm_local_sgd.py
"""
import dataclasses
import json
import time

import jax

from repro.config import (DataConfig, MeshConfig, OptimizerConfig,
                          SyncConfig, TrainConfig, get_smoke)
from repro.core import local_sgd as LS
from repro.core.sync import amortized_bytes_per_step
from repro.data.pipeline import DataPipeline
from repro.launch.mesh import make_test_mesh
from repro.models.registry import analytic_param_count, build_model


def run(strategy: str, period: int, steps: int = 10):
    n_dev = len(jax.devices())
    if n_dev >= 4:
        shape, names = (2, n_dev // 2, 1), ("pod", "data", "model")
    else:
        shape, names = (1, n_dev, 1), ("pod", "data", "model")
    mesh = make_test_mesh(shape, names)
    mesh_cfg = MeshConfig(shape=shape, axis_names=names, replica_axis="pod")

    model_cfg = dataclasses.replace(get_smoke("llama3.2-3b"),
                                    n_layers=4, d_model=256, d_ff=512)
    cfg = TrainConfig(
        model=model_cfg, mesh=mesh_cfg,
        sync=SyncConfig(strategy=strategy, period=period),
        optimizer=OptimizerConfig(name="adamw", learning_rate=1e-3,
                                  schedule="cosine", total_steps=1000),
        data=DataConfig(seq_len=128, global_batch=8))

    model = build_model(cfg.model)
    use_replicas = strategy != "sync_every_step"
    replicas = shape[0] if use_replicas else 0
    with jax.set_mesh(mesh):
        state = LS.init_state(model, cfg, jax.random.key(0),
                              replicas=replicas)
        step = jax.jit(LS.make_train_step(model, cfg, mesh))
        pipe = DataPipeline(cfg.data, cfg.model)
        h = period if use_replicas else 1
        losses = []
        t0 = time.time()
        for _ in range(steps):
            if use_replicas:
                mbs = [next(pipe) for _ in range(h)]
                batch = {k: jax.numpy.stack([m[k] for m in mbs])
                         for k in mbs[0]}
            else:
                batch = next(pipe)
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        wall = time.time() - t0

    params_bytes = analytic_param_count(cfg.model) * 4
    wire = amortized_bytes_per_step(params_bytes, max(shape[0], 2), cfg.sync)
    return {
        "strategy": f"{strategy}(H={period})",
        "params": analytic_param_count(cfg.model),
        "optimizer_steps": steps * h,
        "first_loss": round(losses[0], 3),
        "last_loss": round(losses[-1], 3),
        "wall_s": round(wall, 1),
        "sync_bytes_per_step": int(wire),
    }


def main() -> None:
    print("every-step sync (paper MSF=1 / DDP baseline):")
    a = run("sync_every_step", 1, steps=40)
    print(json.dumps(a, indent=1))
    print("\nperiodic sync over the pod axis (paper's DMS, H=4):")
    b = run("hierarchical", 4, steps=10)   # 10 blocks × H=4 = 40 opt steps
    print(json.dumps(b, indent=1))
    print(f"\nsync bytes/step: {a['sync_bytes_per_step']/1e6:.1f} MB → "
          f"{b['sync_bytes_per_step']/1e6:.1f} MB "
          f"({a['sync_bytes_per_step']/max(1,b['sync_bytes_per_step']):.0f}× "
          f"less DCN traffic at matched optimizer steps)")


if __name__ == "__main__":
    main()
