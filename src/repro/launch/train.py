"""End-to-end training driver.

Wires every subsystem: arch config → mesh → sharding rules → model → MSF
sync engine → optimizer → data pipeline → checkpoint manager →
fault-tolerant step runner. Runs at any scale the process' devices allow —
the CPU smoke path (``--arch smollm-360m --smoke``) and a real pod run use
the same code.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
        --set steps=20 --set sync.strategy=periodic --set sync.period=4
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.config import TrainConfig, config_fingerprint, get_arch, get_smoke
from repro.config.cli import apply_overrides, build_parser
from repro.core import local_sgd as LS
from repro.core import sync as SY
from repro.data.pipeline import DataPipeline
from repro.launch.mesh import (make_production_mesh, make_test_mesh,
                               production_mesh_config, test_mesh_config)
from repro.models.registry import build_model
from repro.runtime import StepRunner
from repro.sharding import rules_for


def build_trainer(cfg: TrainConfig, mesh):
    """Returns (step_fn, initial state, make_pipeline, model, telemetry).

    With ``sync.adaptive`` the step is wrapped in the block-time telemetry
    hook (host-side timer over the sharded jit — donation and shardings
    untouched) and ``telemetry`` is a live
    :class:`repro.core.telemetry.BlockTelemetry`; otherwise ``None``. The
    driver reports the controller's re-solved H at the end of the run —
    changing H *mid-run* recompiles the train block (ROADMAP item), so the
    recommendation feeds the next launch rather than this one.
    """
    rules = rules_for(cfg.mesh, mesh)
    model = build_model(cfg.model, scan_layers=cfg.scan_layers,
                        remat=cfg.remat)
    use_replicas = SY.needs_replica_axis(cfg.sync)
    replicas = cfg.mesh.axis_size(cfg.mesh.replica_axis or "pod") \
        if use_replicas else 0

    with jax.set_mesh(mesh):
        state = LS.init_state(model, cfg, jax.random.key(cfg.seed),
                              replicas=replicas)
        step = LS.make_train_step(model, cfg, mesh, rules)
        axes = LS.build_state_axes(model, cfg, replicated=use_replicas)
        shardings = LS.state_shardings(
            axes, rules, jax.tree.map(lambda x: x.shape, state))
        state = jax.tree.map(jax.device_put, state, shardings)
        jitted = jax.jit(step, in_shardings=(shardings, None),
                         out_shardings=(shardings, None),
                         donate_argnums=(0,))

    h = cfg.sync.period if use_replicas else 0

    telemetry = None
    if cfg.sync.adaptive:
        from repro.core.telemetry import BlockTelemetry
        telemetry = BlockTelemetry()
        # wrap the already-sharded/donating jit — jit_step=False keeps it
        jitted = LS.timed_step(jitted, max(1, h) if use_replicas else 1,
                               telemetry, jit_step=False)

    def make_pipeline(start_step: int):
        pipe = DataPipeline(cfg.data, cfg.model, start_step=start_step)
        if not h:
            return pipe

        class Blocked:
            """Groups H microbatches into one (H, B, …) train block."""

            def __init__(self, inner):
                self.inner = inner

            def state(self):
                return self.inner.state()

            def __iter__(self):
                return self

            def __next__(self):
                mbs = [next(self.inner) for _ in range(h)]
                return {k: jnp.stack([m[k] for m in mbs]) for k in mbs[0]}

        return Blocked(pipe)

    return jitted, state, make_pipeline, model, telemetry


def main() -> None:
    p = build_parser("end-to-end trainer")
    p.add_argument("--smoke", action="store_true",
                   help="reduced config on local devices")
    p.add_argument("--steps", type=int, default=20)
    args = p.parse_args()

    model_cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    if args.smoke:
        n_dev = len(jax.devices())
        mesh = make_test_mesh((n_dev, 1))
        mesh_cfg = test_mesh_config((n_dev, 1))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        mesh_cfg = production_mesh_config(multi_pod=args.multi_pod)

    from repro.config.base import DataConfig
    cfg = TrainConfig(model=model_cfg, mesh=mesh_cfg,
                      data=DataConfig(seq_len=64 if args.smoke else 4096,
                                      global_batch=mesh_cfg.axis_size(
                                          mesh_cfg.data_axis) * 2),
                      steps=args.steps)
    cfg = apply_overrides(cfg, args.overrides)

    step, state, make_pipeline, _, telemetry = build_trainer(cfg, mesh)
    ckpt = CheckpointManager(cfg.checkpoint)
    runner = StepRunner(step, ckpt, cfg.fault, cfg.checkpoint.interval_steps,
                        make_pipeline, fingerprint=config_fingerprint(cfg))

    t0 = time.time()
    with jax.set_mesh(mesh):
        state, final_step = runner.run(state, 0, cfg.steps)
    dt = time.time() - t0
    losses = [m["loss"] for m in runner.metrics_log]
    out = {
        "arch": model_cfg.name,
        "steps": final_step,
        "wall_s": round(dt, 2),
        "first_loss": round(losses[0], 4) if losses else None,
        "last_loss": round(losses[-1], 4) if losses else None,
        "restarts": runner.restarts,
        "stragglers": len(runner.watchdog.events),
    }
    if telemetry is not None:
        # the adaptive re-solve's recommendation for the NEXT launch
        # (H moves recompile the block, so it isn't applied mid-run). A
        # single-H run can't split T_step/T_sync from block times alone;
        # fall back to measured step + analytic sync in that case.
        from repro.core.autotune import DCN_BW, TuneInputs, choose_period
        est = telemetry.estimates()
        t_step = est[0] if est else telemetry.per_step_s()
        rec = None
        if t_step:
            inp = TuneInputs(
                param_bytes_per_chip=max(1, 4 * cfg.model.param_count()
                                         // max(1, mesh.devices.size)),
                replicas=max(2, cfg.mesh.axis_size(cfg.mesh.replica_axis)),
                step_time_s=t_step, link_bw=DCN_BW,
                lr=cfg.optimizer.learning_rate)
            rec = choose_period(
                inp, cfg.sync,
                target_overhead=cfg.sync.adapt_target_overhead,
                max_drift=cfg.sync.adapt_max_drift,
                sync_time_override=est[1] if est else None)
        out["adaptive"] = {"telemetry": telemetry.to_dict(),
                           "recommended_h": rec}
    print(json.dumps(out))


if __name__ == "__main__":
    main()
