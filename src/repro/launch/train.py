"""End-to-end training driver.

Wires every subsystem: arch config → mesh → sharding rules → model → MSF
sync engine → optimizer → data pipeline → checkpoint manager →
fault-tolerant step runner. Runs at any scale the process' devices allow —
the CPU smoke path (``--arch smollm-360m --smoke``) and a real pod run use
the same code.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
        --set steps=20 --set sync.strategy=periodic --set sync.period=4
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.config import TrainConfig, config_fingerprint, get_arch, get_smoke
from repro.config.cli import apply_overrides, build_parser
from repro.core import local_sgd as LS
from repro.core import sync as SY
from repro.data.pipeline import DataPipeline
from repro.launch.mesh import (make_production_mesh, make_test_mesh,
                               production_mesh_config, test_mesh_config)
from repro.models.registry import build_model
from repro.runtime import StepRunner
from repro.sharding import rules_for


class _Blocked:
    """Groups H microbatches into one (H, B, …) train block.

    Assembly is host-side numpy (``DataPipeline.next_host``): the H-ladder
    path feeds the stacked block straight into a pre-compiled executable,
    and any eager jnp op here would compile on first use and break the
    ladder's zero-recompile-after-warmup guarantee.
    """

    def __init__(self, inner, h: int):
        self.inner = inner
        self.h = h

    def state(self):
        return self.inner.state()

    def __iter__(self):
        return self

    def __next__(self):
        mbs = [self.inner.next_host() for _ in range(self.h)]
        return {k: np.stack([m[k] for m in mbs]) for k in mbs[0]}


def _build_ladder(cfg: TrainConfig, mesh, jitted, state, shardings,
                  telemetry, counter, replicas: int):
    """Ladder warmup: AOT-compile every rung + the switch transform, then
    hand them to a :class:`repro.runtime.ladder.LadderRuntime` with the
    controller in ladder mode. ``counter.mark()`` closes the warmup
    window the zero-recompile assertion measures from."""
    from repro.core.autotune import DCN_BW, AdaptiveController
    from repro.runtime.ladder import (LadderRuntime, _avals, compile_rungs)

    rungs = cfg.sync.ladder_rungs()
    sample = DataPipeline(cfg.data, cfg.model).next_host()
    with jax.set_mesh(mesh):
        compiled = compile_rungs(jitted, state, sample, rungs)
        switch = jax.jit(
            lambda s: LS.ladder_switch_state(s, cfg),
            in_shardings=(shardings,), out_shardings=shardings,
            donate_argnums=(0,)).lower(_avals(state)).compile()
    timed = {hh: LS.timed_step(fn, hh, telemetry, jit_step=False)
             for hh, fn in compiled.items()}
    ctrl = AdaptiveController(
        cfg.sync,
        param_bytes_per_chip=max(1, 4 * cfg.model.param_count()
                                 // max(1, mesh.devices.size)),
        replicas=max(2, replicas), link_bw=DCN_BW,
        lr=cfg.optimizer.learning_rate, telemetry=telemetry,
        ladder=rungs)
    if counter is not None:
        counter.mark()
    return LadderRuntime(timed, switch, ctrl, telemetry=telemetry,
                         shardings=shardings, compile_counter=counter)


def build_trainer(cfg: TrainConfig, mesh):
    """Returns (step_fn, initial state, make_pipeline, model, telemetry,
    ladder).

    With ``sync.adaptive`` on a replica-sync strategy the trainer builds
    the **H-ladder runtime**: the train block is AOT-compiled for every
    rung of ``cfg.sync.ladder_rungs()`` (shared state layout — one traced
    signature, one executable per batch shape), the switch transform is
    AOT-compiled too, and ``ladder`` is a live
    :class:`repro.runtime.ladder.LadderRuntime` the step runner drives —
    the controller moves H *mid-run* with zero XLA compiles after the
    ladder warmup (counted by the ladder's ``CompileCounter``). In that
    mode ``step_fn`` is the un-warmed jit and must not be called directly
    (use ``ladder.step_fn``). With ``sync.adaptive`` on ``sync_every_step``
    the step is only wrapped in the block-time telemetry hook and the
    driver reports a recommendation for the next launch; ``telemetry`` is
    a live :class:`repro.core.telemetry.BlockTelemetry` in both adaptive
    modes, ``None`` otherwise.
    """
    rules = rules_for(cfg.mesh, mesh)
    model = build_model(cfg.model, scan_layers=cfg.scan_layers,
                        remat=cfg.remat)
    use_replicas = SY.needs_replica_axis(cfg.sync)
    replicas = cfg.mesh.axis_size(cfg.mesh.replica_axis or "pod") \
        if use_replicas else 0

    build_ladder = cfg.sync.adaptive and use_replicas
    counter = None
    if build_ladder:
        # install before any compilation so warmup compiles are counted
        # (and everything after mark() must be zero)
        from repro.runtime.ladder import CompileCounter
        counter = CompileCounter().install()

    with jax.set_mesh(mesh):
        state = LS.init_state(model, cfg, jax.random.key(cfg.seed),
                              replicas=replicas)
        step = LS.make_train_step(model, cfg, mesh, rules)
        axes = LS.build_state_axes(model, cfg, replicated=use_replicas)
        shardings = LS.state_shardings(
            axes, rules, jax.tree.map(lambda x: x.shape, state))
        state = jax.tree.map(jax.device_put, state, shardings)
        jitted = jax.jit(step, in_shardings=(shardings, None),
                         out_shardings=(shardings, None),
                         donate_argnums=(0,))

    h = cfg.sync.period if use_replicas else 0

    telemetry = None
    ladder = None
    if cfg.sync.adaptive:
        from repro.core.telemetry import BlockTelemetry
        telemetry = BlockTelemetry()
        if build_ladder:
            ladder = _build_ladder(cfg, mesh, jitted, state, shardings,
                                   telemetry, counter, replicas)
        else:
            # wrap the already-sharded/donating jit — jit_step=False
            # keeps it
            jitted = LS.timed_step(jitted, 1, telemetry, jit_step=False)

    def make_pipeline(start_step: int):
        pipe = DataPipeline(cfg.data, cfg.model, start_step=start_step)
        cur_h = ladder.h if ladder is not None else h
        if not cur_h:
            return pipe
        return _Blocked(pipe, cur_h)

    return jitted, state, make_pipeline, model, telemetry, ladder


def main() -> None:
    p = build_parser("end-to-end trainer")
    p.add_argument("--smoke", action="store_true",
                   help="reduced config on local devices")
    p.add_argument("--steps", type=int, default=20)
    args = p.parse_args()

    model_cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    if args.smoke:
        n_dev = len(jax.devices())
        mesh = make_test_mesh((n_dev, 1))
        mesh_cfg = test_mesh_config((n_dev, 1))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        mesh_cfg = production_mesh_config(multi_pod=args.multi_pod)

    from repro.config.base import DataConfig
    cfg = TrainConfig(model=model_cfg, mesh=mesh_cfg,
                      data=DataConfig(seq_len=64 if args.smoke else 4096,
                                      global_batch=mesh_cfg.axis_size(
                                          mesh_cfg.data_axis) * 2),
                      steps=args.steps)
    cfg = apply_overrides(cfg, args.overrides)

    step, state, make_pipeline, _, telemetry, ladder = build_trainer(cfg,
                                                                     mesh)
    ckpt = CheckpointManager(cfg.checkpoint)
    runner = StepRunner(step, ckpt, cfg.fault, cfg.checkpoint.interval_steps,
                        make_pipeline, fingerprint=config_fingerprint(cfg),
                        ladder=ladder)

    t0 = time.time()
    with jax.set_mesh(mesh):
        state, final_step = runner.run(state, 0, cfg.steps)
    dt = time.time() - t0
    losses = [m["loss"] for m in runner.metrics_log]
    out = {
        "arch": model_cfg.name,
        "steps": final_step,
        "wall_s": round(dt, 2),
        "first_loss": round(losses[0], 4) if losses else None,
        "last_loss": round(losses[-1], 4) if losses else None,
        "restarts": runner.restarts,
        "stragglers": len(runner.watchdog.events),
    }
    if ladder is not None:
        # the live H-ladder run: trajectory, switches, per-rung telemetry
        # and the compile count the adaptive-smoke CI job asserts on
        out["adaptive"] = ladder.to_dict()
        out["adaptive"]["controller_history"] = [
            list(t) for t in ladder.controller.history]
    elif telemetry is not None:
        out["adaptive"] = adaptive_report(cfg, mesh, telemetry)
    print(json.dumps(out))


def adaptive_report(cfg: TrainConfig, mesh, telemetry) -> dict:
    """The non-ladder adaptive summary: the re-solve's recommendation for
    the NEXT launch (``sync_every_step`` has no block to ladder). A
    single-H run can't split T_step/T_sync from block times alone; fall
    back to measured step + analytic sync in that case. The replica count
    uses the same ``or "pod"`` fallback as ``build_trainer`` — an unset
    ``replica_axis`` must not change which axis the report prices."""
    from repro.core.autotune import DCN_BW, TuneInputs, choose_period
    est = telemetry.estimates()
    t_step = est[0] if est else telemetry.per_step_s()
    rec = None
    if t_step:
        inp = TuneInputs(
            param_bytes_per_chip=max(1, 4 * cfg.model.param_count()
                                     // max(1, mesh.devices.size)),
            replicas=max(2, cfg.mesh.axis_size(
                cfg.mesh.replica_axis or "pod")),
            step_time_s=t_step, link_bw=DCN_BW,
            lr=cfg.optimizer.learning_rate)
        rec = choose_period(
            inp, cfg.sync,
            target_overhead=cfg.sync.adapt_target_overhead,
            max_drift=cfg.sync.adapt_max_drift,
            sync_time_override=est[1] if est else None)
    return {"telemetry": telemetry.to_dict(), "recommended_h": rec}


if __name__ == "__main__":
    main()
