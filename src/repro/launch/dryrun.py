import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below this line may import jax -----------------------------
"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes, record memory/cost/collective analysis for §Dry-run and
§Roofline.

The two lines above MUST stay first: jax locks the device count at first
init, and the dry-run needs 512 placeholder host devices to build the
2×16×16 production mesh. (Smoke tests / benchmarks never import this
module — they see the real single CPU device.)

Usage:
    python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.config import SyncConfig, get_arch, list_archs
from repro.launch.mesh import make_production_mesh, production_mesh_config
from repro.launch.roofline import compute_terms
from repro.launch.specs import SHAPE_CELLS, build_cell, cell_runnable


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             sync: SyncConfig | None = None, remat: str = "full",
             rule_overrides: dict | None = None,
             verbose: bool = True) -> dict:
    """Lower + compile one cell; returns the result record (never raises)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_cfg = production_mesh_config(multi_pod=multi_pod)
    cfg = get_arch(arch)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "kind": SHAPE_CELLS[shape].kind,
        "status": "ok",
    }
    ok, reason = cell_runnable(cfg, shape)
    if not ok:
        rec.update(status="skip", reason=reason)
        return rec

    if sync is None and multi_pod and SHAPE_CELLS[shape].kind == "train":
        # default multi-pod train flavor: the paper's technique — periodic
        # (hierarchical) sync across the pod/DCN axis, H=8 local steps
        sync = SyncConfig(strategy="hierarchical", period=8)

    t0 = time.time()
    try:
        built = build_cell(arch, shape, mesh, mesh_cfg, sync=sync,
                           remat=remat, rule_overrides=rule_overrides)
        with jax.set_mesh(mesh):
            lowered = built.step.lower(*built.args_sds)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
    except Exception as e:  # noqa: BLE001 — a failed cell is a data point
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        return rec
    compile_s = time.time() - t0

    n_dev = 512 if multi_pod else 256
    pod_axis = 2 if multi_pod else 0
    terms = compute_terms(cost, hlo, total_devices=n_dev,
                          model_flops=built.model_flops,
                          pod_axis_size=pod_axis)

    mem_rec = {f: int(getattr(mem, f)) for f in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes")}
    # per-device residency: donated args alias outputs
    resident = (mem_rec["argument_size_in_bytes"]
                + mem_rec["output_size_in_bytes"]
                + mem_rec["temp_size_in_bytes"]
                - mem_rec["alias_size_in_bytes"])
    # local-SGD train blocks compile H optimizer steps into one call —
    # record it so per-step roofline comparisons normalize correctly
    opt_steps = (sync.period if sync is not None
                 and sync.strategy in ("periodic", "hierarchical")
                 and SHAPE_CELLS[shape].kind == "train" else 1)
    rec.update(
        sync=dataclasses.asdict(sync) if sync else None,
        opt_steps_per_call=opt_steps,
        compile_s=round(compile_s, 1),
        params=built.param_count,
        active_params=built.active_param_count,
        memory=mem_rec,
        resident_bytes_per_device=resident,
        fits_16g=resident < 16e9,
        cost={k: cost.get(k) for k in ("flops", "bytes accessed")},
        roofline=dataclasses.asdict(terms),
    )
    if verbose:
        print(f"[{mesh_name}] {arch} × {shape}: compile {compile_s:.0f}s | "
              f"resident {resident/1e9:.2f} GB/dev (fits16G={rec['fits_16g']})"
              f" | compute {terms.compute_s*1e3:.2f}ms"
              f" memory {terms.memory_s*1e3:.2f}ms"
              f" collective {terms.collective_s*1e3:.2f}ms"
              f" → {terms.dominant}-bound | useful {terms.useful_ratio:.2f}")
        print(f"    memory_analysis: {mem}")
    return rec


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None,
                   choices=list(SHAPE_CELLS) + [None])
    p.add_argument("--all", action="store_true", help="run every cell")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--sync-strategy", default=None,
                   choices=[None, "sync_every_step", "periodic",
                            "hierarchical"])
    p.add_argument("--sync-period", type=int, default=8)
    p.add_argument("--compression", default="none", choices=["none", "int8"])
    p.add_argument("--remat", default="full",
                   choices=["none", "full", "dots"])
    p.add_argument("--out", default="experiments/dryrun")
    args = p.parse_args()

    sync = None
    if args.sync_strategy:
        sync = SyncConfig(strategy=args.sync_strategy,
                          period=args.sync_period,
                          compression=args.compression)

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPE_CELLS)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if not (args.all or args.arch):
        p.error("pass --arch or --all")

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_err = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, multi_pod=multi_pod, sync=sync,
                               remat=args.remat)
                tag = "2x16x16" if multi_pod else "16x16"
                fname = f"{arch}__{shape}__{tag}.json".replace("/", "_")
                with open(os.path.join(args.out, fname), "w") as f:
                    json.dump(rec, f, indent=1)
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skip"
                n_err += rec["status"] == "error"
                if rec["status"] == "error":
                    print(f"[{tag}] {arch} × {shape}: ERROR "
                          f"{rec['error'][:300]}")
                elif rec["status"] == "skip":
                    print(f"[{tag}] {arch} × {shape}: SKIP ({rec['reason']})")
    print(f"\ndry-run summary: {n_ok} ok / {n_skip} skip / {n_err} error")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
