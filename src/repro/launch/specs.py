"""Shape cells, input specs, and step-builders for dry-run/train/serve.

A *cell* = (architecture × input shape). ``build_cell`` returns everything
needed to lower it on a mesh: the jitted step function and the
ShapeDtypeStruct arguments (no allocation — the shannon/kernels pattern).

Cells (LM shapes are seq_len × global_batch):
    train_4k     S=4096   B=256   → train_step   (DDP or MSF local-SGD)
    prefill_32k  S=32768  B=32    → prefill_step
    decode_32k   S=32768  B=128   → serve_step (1 token vs S-long KV cache)
    long_500k    S=524288 B=1     → serve_step; sub-quadratic archs only
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.config import TrainConfig, get_arch
from repro.config.base import MeshConfig, ModelConfig, SyncConfig
from repro.core import local_sgd as LS
from repro.core import sync as SY
from repro.models.registry import analytic_param_count, build_model
from repro.sharding import ShardingRules, rules_for, use_rules


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPE_CELLS: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode", 32_768, 128),
    "long_500k": ShapeCell("decode", 524_288, 1),
}


def cell_runnable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    """The brief's mandated skips."""
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 524k-token decode is "
                       "quadratic/cache-infeasible — mandated skip")
    return True, ""


# ---------------------------------------------------------------------------
# spec construction
# ---------------------------------------------------------------------------

def _is_layout_leaf(x) -> bool:
    return (isinstance(x, tuple) and len(x) == 3
            and isinstance(x[0], tuple))


def layout_to_sds(layout, rules: ShardingRules):
    """(shape, dtype, axes) triples → (SDS pytree, NamedSharding pytree)."""
    def sds(leaf):
        shape, dtype, axes = leaf
        return jax.ShapeDtypeStruct(shape, dtype)

    def sh(leaf):
        shape, dtype, axes = leaf
        return rules.sharding_for(axes, shape)

    sds_tree = jax.tree.map(sds, layout, is_leaf=_is_layout_leaf)
    sh_tree = jax.tree.map(sh, layout, is_leaf=_is_layout_leaf)
    return sds_tree, sh_tree


def state_specs(model, tcfg: TrainConfig, rules: ShardingRules,
                replicas: int = 0):
    """TrainState SDS + shardings via eval_shape (no allocation)."""
    state_sds = jax.eval_shape(
        lambda: LS.init_state(model, tcfg, jax.random.key(0),
                              replicas=replicas))
    axes = LS.build_state_axes(model, tcfg, replicated=replicas > 0)
    shapes = jax.tree.map(lambda s: s.shape, state_sds)
    shardings = jax.tree.map(
        lambda la, shp: rules.sharding_for(la, shp), axes, shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    return state_sds, shardings


def _cast_tree(sds_tree, dtype):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype)
        if jnp.issubdtype(s.dtype, jnp.floating) else s, sds_tree)


# ---------------------------------------------------------------------------
# cell builder
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BuiltCell:
    arch: str
    shape_name: str
    kind: str
    step: Callable                 # jitted, ready to .lower(*args)
    args_sds: Tuple[Any, ...]
    model_flops: float             # 6·N_active·tokens (train) / 2·N·tok
    param_count: int
    active_param_count: int
    notes: str = ""


def model_flops_estimate(cfg: ModelConfig, kind: str, batch: int,
                         seq: int) -> float:
    n_active = analytic_param_count(cfg, active_only=True)
    if kind == "train":
        return 6.0 * n_active * batch * seq
    if kind == "prefill":
        return 2.0 * n_active * batch * seq
    if kind == "decode":
        return 2.0 * n_active * batch      # one token per request
    raise ValueError(kind)


def make_train_config(cfg: ModelConfig, mesh_cfg: MeshConfig, cell: ShapeCell,
                      sync: Optional[SyncConfig] = None,
                      optimizer: str = "adamw", remat: str = "full",
                      ) -> TrainConfig:
    from repro.config.base import DataConfig, OptimizerConfig
    # ≥100B params: bf16 adam moments, or optimizer state alone overflows
    # a single pod's HBM (Gopher-style bf16 statistics)
    moment_dtype = ("bfloat16"
                    if analytic_param_count(cfg) > 100e9 else "float32")
    return TrainConfig(
        model=cfg,
        mesh=mesh_cfg,
        sync=sync or SyncConfig(),
        optimizer=OptimizerConfig(name=optimizer, learning_rate=3e-4,
                                  schedule="cosine", warmup_steps=100,
                                  total_steps=10_000, grad_clip=1.0,
                                  moment_dtype=moment_dtype),
        data=DataConfig(seq_len=cell.seq, global_batch=cell.batch),
        remat=remat,
    )


def build_cell(arch: str, shape_name: str, mesh: Mesh, mesh_cfg: MeshConfig,
               *, sync: Optional[SyncConfig] = None,
               remat: str = "full", attn_impl: str = "jnp",
               serve_dtype=jnp.bfloat16,
               rule_overrides: Optional[dict] = None,
               cfg_override: Optional[ModelConfig] = None) -> BuiltCell:
    cell = SHAPE_CELLS[shape_name]
    cfg = cfg_override or get_arch(arch)
    ok, reason = cell_runnable(cfg, shape_name)
    if not ok:
        raise ValueError(f"cell {arch}×{shape_name} skipped: {reason}")

    rules = rules_for(mesh_cfg, mesh, overrides=rule_overrides)
    mf = model_flops_estimate(cfg, cell.kind, cell.batch, cell.seq)
    common = dict(arch=arch, shape_name=shape_name, kind=cell.kind,
                  model_flops=mf,
                  param_count=analytic_param_count(cfg),
                  active_param_count=analytic_param_count(cfg, True))

    if cell.kind == "train":
        model = build_model(cfg, scan_layers=True, remat=remat,
                            attn_impl=attn_impl)
        tcfg = make_train_config(cfg, mesh_cfg, cell, sync=sync, remat=remat)
        use_local_sgd = SY.needs_replica_axis(tcfg.sync)
        replicas = mesh_cfg.axis_size(mesh_cfg.replica_axis) \
            if use_local_sgd else 0
        state_sds, state_sh = state_specs(model, tcfg, rules,
                                          replicas=replicas)
        layout = model.input_layout("train", cell.batch, cell.seq)

        if use_local_sgd:
            # batch gains a leading H (microbatch) dim; B shards over
            # (pod, data) — each pod replica consumes its own rows
            h = max(1, tcfg.sync.period)
            batch_rules = rules_for(
                mesh_cfg, mesh,
                overrides={**(rule_overrides or {}),
                           "batch": (mesh_cfg.replica_axis or "pod",
                                     mesh_cfg.data_axis)})
            layout = jax.tree.map(
                lambda leaf: ((h,) + leaf[0], leaf[1], (None,) + leaf[2]),
                layout, is_leaf=_is_layout_leaf)
            batch_sds, batch_sh = layout_to_sds(layout, batch_rules)
            step = LS.make_local_sgd_block(model, tcfg, mesh, rules)
        else:
            if mesh_cfg.replica_axis:
                # every-step DDP on the multi-pod mesh: batch shards over
                # pod × data, gradients all-reduce over both
                batch_rules = rules_for(
                    mesh_cfg, mesh,
                    overrides={**(rule_overrides or {}),
                               "batch": (mesh_cfg.replica_axis,
                                         mesh_cfg.data_axis)})
            else:
                batch_rules = rules
            batch_sds, batch_sh = layout_to_sds(layout, batch_rules)
            # the model-internal constraints must match: on the multi-pod
            # mesh DDP shards batch over pod×data INSIDE the step too
            step = LS.make_ddp_step(model, tcfg, mesh, batch_rules)

        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        return BuiltCell(step=jitted, args_sds=(state_sds, batch_sds),
                         **common)

    # ---- serving kinds ----
    model = build_model(cfg, scan_layers=True, remat="none",
                        attn_impl=attn_impl)
    serve_batch_axes = ((mesh_cfg.replica_axis, mesh_cfg.data_axis)
                        if mesh_cfg.replica_axis else (mesh_cfg.data_axis,))
    serve_rules = rules_for(mesh_cfg, mesh,
                            overrides={**(rule_overrides or {}),
                                       "batch": serve_batch_axes})
    params_sds = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    params_sds = _cast_tree(params_sds, serve_dtype)
    from repro.models import layers as L
    param_axes = L.axes_of(model.param_defs())
    params_sh = jax.tree.map(
        lambda la, s: serve_rules.sharding_for(la, s.shape),
        param_axes, params_sds,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))

    if cell.kind == "prefill":
        layout = model.input_layout("prefill", cell.batch, cell.seq)
        batch_sds, batch_sh = layout_to_sds(layout, serve_rules)
        # pin the output cache to the decode-ready (cache_seq-sharded)
        # layout — the prefill→decode handoff reshard
        cache_layout = model.input_layout("decode", cell.batch,
                                          cell.seq)["cache"]
        _, cache_sh = layout_to_sds(cache_layout, serve_rules)

        def prefill_step(params, batch):
            with use_rules(serve_rules):
                return model.prefill(params, batch)

        jitted = jax.jit(prefill_step,
                         in_shardings=(params_sh, batch_sh),
                         out_shardings=(None, cache_sh))
        return BuiltCell(step=jitted, args_sds=(params_sds, batch_sds),
                         **common)

    # decode
    layout = model.input_layout("decode", cell.batch, cell.seq)
    batch_sds, batch_sh = layout_to_sds(layout, serve_rules)
    batch_sds = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, serve_dtype)
        if jnp.issubdtype(s.dtype, jnp.bfloat16) else s, batch_sds)

    def serve_step(params, batch):
        with use_rules(serve_rules):
            return model.decode_step(params, batch)

    jitted = jax.jit(serve_step, in_shardings=(params_sh, batch_sh),
                     out_shardings=(None, batch_sh["cache"]),
                     donate_argnums=(1,))
    return BuiltCell(step=jitted, args_sds=(params_sds, batch_sds), **common)
