"""Batched serving driver: prefill → decode loop with a request queue.

Serving path of the framework: requests arrive with prompts, get batched
to the configured batch size, prefilled once (cache written decode-ready),
then stepped token-by-token. Params are cast to bf16. The same code path
runs the CPU smoke demo and a pod deployment.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --requests 4 --gen-tokens 8
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch, get_smoke
from repro.config.cli import build_parser
from repro.launch.mesh import (make_production_mesh, make_test_mesh,
                               production_mesh_config, test_mesh_config)
from repro.models.registry import build_model
from repro.sharding import rules_for, use_rules


class ServeEngine:
    def __init__(self, model_cfg, mesh, mesh_cfg, max_len: int = 128,
                 dtype=jnp.bfloat16):
        self.cfg = model_cfg
        self.mesh = mesh
        self.rules = rules_for(mesh_cfg, mesh)
        self.model = build_model(model_cfg)
        self.max_len = max_len
        self.dtype = dtype
        with jax.set_mesh(mesh), use_rules(self.rules):
            params = self.model.init(jax.random.key(0))
            self.params = jax.tree.map(
                lambda p: p.astype(dtype)
                if jnp.issubdtype(p.dtype, jnp.floating) else p, params)

        def prefill(params, batch):
            with use_rules(self.rules):
                return self.model.prefill(params, batch)

        def decode(params, batch):
            with use_rules(self.rules):
                return self.model.decode_step(params, batch)

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode, donate_argnums=(1,))

    def generate(self, prompts: np.ndarray, gen_tokens: int,
                 extras=None) -> np.ndarray:
        """prompts: (B, S_prompt) int32 → (B, gen_tokens) int32 greedy."""
        b, s_prompt = prompts.shape
        batch = {"tokens": jnp.asarray(prompts)}
        if extras:
            batch.update(extras)
        with jax.set_mesh(self.mesh):
            logits, cache = self._prefill(self.params, batch)
            # grow the prefill cache out to max_len for decode-in-place
            cache = self._grow_cache(cache, b)
            out = []
            index = s_prompt
            token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            for _ in range(gen_tokens):
                out.append(np.asarray(token)[:, 0])
                logits, cache = self._decode(
                    self.params, {"token": token, "cache": cache,
                                  "index": jnp.int32(index)})
                token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
                index += 1
        return np.stack(out, axis=1)

    def _grow_cache(self, cache, batch_size: int):
        """Pad seq-dim cache buffers from prompt length to max_len."""
        full = self.model.init_cache(batch_size, self.max_len,
                                     dtype=self.dtype)

        def merge(dst, src):
            if dst.shape == src.shape:
                return src.astype(dst.dtype)
            pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            return jnp.pad(src.astype(dst.dtype), pad)

        return jax.tree.map(merge, full, cache)


def main() -> None:
    p = build_parser("batched serving driver")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--gen-tokens", type=int, default=8)
    args = p.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    if args.smoke:
        n_dev = len(jax.devices())
        mesh, mesh_cfg = make_test_mesh((n_dev, 1)), test_mesh_config((n_dev, 1))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        mesh_cfg = production_mesh_config(multi_pod=args.multi_pod)

    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab_size,
                           size=(args.requests, args.prompt_len),
                           dtype=np.int32)
    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = jnp.zeros(
            (args.requests, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        extras["frames"] = jnp.zeros(
            (args.requests, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)

    engine = ServeEngine(cfg, mesh, mesh_cfg,
                         max_len=args.prompt_len + args.gen_tokens + 1)
    t0 = time.time()
    tokens = engine.generate(prompts, args.gen_tokens, extras=extras)
    dt = time.time() - t0
    print(json.dumps({
        "arch": cfg.name,
        "requests": args.requests,
        "generated": tokens.shape[1],
        "tokens_per_s": round(tokens.size / dt, 1),
        "sample": tokens[0].tolist(),
    }))


if __name__ == "__main__":
    main()
