"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — required because the dry-run must
set XLA_FLAGS before any jax initialization, and smoke tests must see the
real single-device CPU.

Production target: TPU v5e pods, 256 chips each.
  single-pod:  (data=16, model=16)           — the roofline-table mesh
  multi-pod:   (pod=2, data=16, model=16)    — 512 chips; `pod` is the DCN
               axis the MSF (local-SGD) schedule syncs across.
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.config.base import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def production_mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    if multi_pod:
        return MeshConfig(shape=(2, 16, 16),
                          axis_names=("pod", "data", "model"),
                          replica_axis="pod")
    return MeshConfig(shape=(16, 16), axis_names=("data", "model"))


def make_test_mesh(shape: Tuple[int, ...] = (1, 1),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh over however many (host) devices the test process has."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def test_mesh_config(shape: Tuple[int, ...] = (1, 1),
                     axes: Tuple[str, ...] = ("data", "model")) -> MeshConfig:
    replica = "pod" if "pod" in axes else ""
    return MeshConfig(shape=shape, axis_names=axes, replica_axis=replica)
