"""Roofline-term extraction: a loop-aware HLO cost model.

Why not ``compiled.cost_analysis()``: XLA counts a while-loop body ONCE
regardless of trip count, so a 94-layer ``lax.scan`` model reports ~1/94th
of its FLOPs. Fully unrolling for analysis explodes compile time (and CPU
scheduling pollutes the byte counts). Instead we parse the
post-optimization HLO text ourselves:

1. split into computations; record every instruction's result type;
2. find ``while`` ops — their ``backend_config`` carries
   ``known_trip_count`` — and propagate multipliers into (nested) body
   computations; only ENTRY + while-bodies are costed;
3. FLOPs: ``dot`` ops → 2 · numel(result) · K (K = product of the lhs
   contracting dims — exact for the matmul-dominated cells; elementwise
   FLOPs are ignored, noted as a known undercount of a few %);
4. HBM bytes: per instruction, result bytes + operand bytes (fusion ops
   count at the call site and their internals are free — mirroring XLA's
   own fusion-aware accounting);
5. collective wire bytes: result-shape bytes × the ring-algorithm factor
   for the op's group size K, with ``-start``/``-done`` pairs counted once.

Everything is per-device (the SPMD module is the per-device program).

Terms (TPU v5e):
    compute    = flops / 197e12        memory = hbm_bytes / 819e9
    collective = ici_bytes / 50e9 + dcn_bytes / 6.25e9
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per chip (intra-pod)
DCN_BW = 6.25e9            # bytes/s per chip (cross-pod, 50 Gbps)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "while", "conditional", "after-all",
                   "partition-id", "replica-id", "custom-call"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\((.*?)\)\s*->")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*([^,()]+(?:\([^)]*\))?)")
_TRIP_RE = re.compile(r'known_trip_count"?\s*:\s*\{"?n"?:"?(\d+)')
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=(\S*)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_info(shape_text: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """Total bytes + per-array (dtype, dims) of an HLO type string."""
    total = 0
    arrays = []
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x]
        n = 1
        for v in d:
            n *= v
        total += n * _DTYPE_BYTES[dtype]
        arrays.append((dtype, d))
    return total, arrays


@dataclasses.dataclass
class _Instr:
    name: str
    ret_type: str
    op: str
    line: str
    bytes: int
    dims: List[Tuple[str, List[int]]]


@dataclasses.dataclass
class _Computation:
    name: str
    instrs: List[_Instr]
    symbols: Dict[str, _Instr]
    whiles: List[Tuple[str, int]]        # (body computation, trip count)


def _parse_computations(hlo: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    current: Optional[_Computation] = None
    entry_name = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):          # possible computation header
            m = _COMP_HEADER_RE.match(line)
            if m:
                current = _Computation(m.group(1), [], {}, [])
                comps[m.group(1)] = current
                if line.startswith("ENTRY"):
                    entry_name = m.group(1)
                # header params are symbols too
                for pname, ptype in _PARAM_RE.findall(m.group(2)):
                    b, dims = _shape_info(ptype)
                    current.symbols[pname] = _Instr(pname, ptype,
                                                    "parameter", line, b,
                                                    dims)
                continue
            if line.startswith("}"):
                current = None
                continue
        if current is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, ret_type, op = m.group(1), m.group(2), m.group(3)
        b, dims = _shape_info(ret_type)
        ins = _Instr(name, ret_type, op, line, b, dims)
        current.instrs.append(ins)
        current.symbols[name] = ins
        if op == "while":
            bm = _BODY_RE.search(line)
            tm = _TRIP_RE.search(line)
            trip = int(tm.group(1)) if tm else 1
            if bm:
                current.whiles.append((bm.group(1), trip))
    comps["__entry__"] = comps.get(entry_name) or next(iter(comps.values()))
    return comps


def _multipliers(comps: Dict[str, _Computation]) -> Dict[str, float]:
    """computation name → execution count (ENTRY + nested while bodies)."""
    entry = comps["__entry__"]
    mult: Dict[str, float] = {entry.name: 1.0}
    frontier = [entry.name]
    while frontier:
        cname = frontier.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        for body, trip in comp.whiles:
            add = mult[cname] * trip
            if body in mult:
                mult[body] += add
            else:
                mult[body] = add
                frontier.append(body)
    return mult


_IOTA_FULL_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def _group_info(line: str, default: int, pod_stride: int):
    """→ (group_size, is_dcn). A collective crosses pods (DCN) when a
    group's member ids span ≥ pod_stride (pods are the major mesh dim).
    Iota-form groups are reconstructed exactly (N ≤ 512 — cheap)."""
    import numpy as _np

    m = _IOTA_FULL_RE.search(line)
    if m:
        g, k = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = _np.arange(int(_np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(g, k)
        spread = int(groups[0].max() - groups[0].min()) if k > 1 else 0
        return max(1, k), pod_stride > 0 and spread >= pod_stride
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x.strip()]
        k = max(1, len(ids))
        is_dcn = pod_stride > 0 and ids and (max(ids) - min(ids)) >= pod_stride
        return k, is_dcn
    return default, False


def _wire_factor(op: str, k: int) -> float:
    if op == "all-reduce":
        return 2.0 * (k - 1) / k
    if op == "all-gather":
        return (k - 1) / k
    if op == "reduce-scatter":
        return float(k - 1)
    if op == "all-to-all":
        return (k - 1) / k
    if op == "collective-permute":
        return 1.0
    return 1.0


def _dot_flops(ins: _Instr, comp: _Computation) -> float:
    """2 · numel(result) · K for a dot instruction."""
    out_numel = 1
    for _, dims in ins.dims:
        for d in dims:
            out_numel *= d
    cm = _CONTRACT_RE.search(ins.line)
    # first operand = lhs
    paren = ins.line.find(ins.op + "(")
    operands = _OPERAND_RE.findall(
        ins.line[paren:ins.line.find(")", paren)])
    k = 1
    if cm and operands:
        lhs = comp.symbols.get(operands[0])
        if lhs is not None and lhs.dims:
            lhs_dims = lhs.dims[0][1]
            for ci in cm.group(1).split(","):
                if ci:
                    idx = int(ci)
                    if idx < len(lhs_dims):
                        k *= lhs_dims[idx]
    return 2.0 * out_numel * k


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    dcn_wire_bytes: float = 0.0
    dots: int = 0
    collectives: Dict[str, dict] = dataclasses.field(default_factory=dict)


def analyze_hlo(hlo: str, total_devices: int,
                pod_axis_size: int = 0) -> HloCost:
    comps = _parse_computations(hlo)
    mult = _multipliers(comps)
    cost = HloCost()
    for cname, m in mult.items():
        comp = comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instrs:
            op = ins.op
            if op.endswith("-done"):
                continue
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES:
                pod_stride = (total_devices // pod_axis_size
                              if pod_axis_size else 0)
                k, is_dcn = _group_info(ins.line, total_devices, pod_stride)
                wire = ins.bytes * _wire_factor(base, k) * m
                st = cost.collectives.setdefault(
                    base, {"count": 0, "wire_bytes": 0.0, "groups": {}})
                st["count"] += int(m)
                st["wire_bytes"] += wire
                st["groups"][str(k)] = st["groups"].get(str(k), 0) + int(m)
                if is_dcn:
                    cost.dcn_wire_bytes += wire
                else:
                    cost.coll_wire_bytes += wire
                cost.hbm_bytes += ins.bytes * m      # HBM side of the wire
                continue
            if op == "dot":
                cost.flops += _dot_flops(ins, comp) * m
                cost.dots += 1
            if op in _SKIP_BYTES_OPS:
                continue
            # fusion-aware bytes: result write + operand reads
            paren = ins.line.find(op + "(")
            close = ins.line.find(")", paren)
            operands = _OPERAND_RE.findall(ins.line[paren:close])
            ob = sum(comp.symbols[o].bytes for o in operands
                     if o in comp.symbols)
            cost.hbm_bytes += (ins.bytes + ob) * m
    return cost


@dataclasses.dataclass
class RooflineTerms:
    flops: float               # per-chip HLO flops (loop-aware)
    hbm_bytes: float           # per-chip bytes accessed (loop-aware)
    ici_wire_bytes: float      # per-chip collective bytes (intra-pod)
    dcn_wire_bytes: float      # per-chip collective bytes (cross-pod)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float        # MODEL_FLOPS / (HLO flops × chips)
    mfu_bound: float           # MODEL_FLOPS/(chips·peak) / max(term)
    collectives: Dict[str, dict]
    xla_cost: Optional[dict] = None    # raw cost_analysis for reference

    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def compute_terms(cost: dict, hlo_text: str, *, total_devices: int,
                  model_flops: float, pod_axis_size: int = 0
                  ) -> RooflineTerms:
    h = analyze_hlo(hlo_text, total_devices, pod_axis_size)
    compute_s = h.flops / PEAK_FLOPS
    memory_s = h.hbm_bytes / HBM_BW
    collective_s = h.coll_wire_bytes / ICI_BW + h.dcn_wire_bytes / DCN_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    useful = model_flops / max(1.0, h.flops * total_devices)
    ideal_s = model_flops / (total_devices * PEAK_FLOPS)
    mfu_bound = ideal_s / max(1e-12, max(compute_s, memory_s, collective_s))
    return RooflineTerms(
        flops=h.flops, hbm_bytes=h.hbm_bytes,
        ici_wire_bytes=h.coll_wire_bytes, dcn_wire_bytes=h.dcn_wire_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops, useful_ratio=useful,
        mfu_bound=mfu_bound,
        collectives=h.collectives,
        xla_cost={k: cost.get(k) for k in ("flops", "bytes accessed")})
