"""Atomic, keep-k checkpointing of arbitrary pytrees (no external deps).

Layout::

    <dir>/step_000123/          # one directory per step
        manifest.json           # treedef paths, shapes, dtypes, fingerprint
        arrays.npz              # all leaves, keyed by flattened path
    <dir>/LATEST                # text file: "step_000123"

Atomicity: write into ``<dir>/.tmp_step_x``, fsync, then ``os.rename`` —
rename is atomic on POSIX, so a crash mid-write never corrupts LATEST.
Multi-host: only process 0 writes (single-controller pattern); every leaf is
gathered to host first via ``jax.device_get`` (for sharded arrays this is the
fully-replicated global value — fine at the model sizes we checkpoint in
tests; a real deployment would swap in per-shard writes behind the same
interface, which is why ``_gather`` is a seam).

``async_write=True`` moves serialization+IO to a daemon thread; ``wait()``
joins outstanding writes (called before restore and at exit).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.config.base import CheckpointConfig


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        self.directory = cfg.directory
        os.makedirs(self.directory, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: List[threading.Thread] = []

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, extra: Optional[Dict[str, Any]] = None,
             fingerprint: str = "") -> None:
        # materialize on host *before* any thread handoff so the caller can
        # keep mutating device state
        leaves = [(k, np.asarray(jax.device_get(v)))
                  for k, v in _flatten_with_paths(state)]
        if self.cfg.async_write:
            t = threading.Thread(
                target=self._write, args=(step, leaves, extra, fingerprint),
                daemon=True)
            t.start()
            with self._lock:
                self._pending.append(t)
        else:
            self._write(step, leaves, extra, fingerprint)

    def _write(self, step: int, leaves, extra, fingerprint: str) -> None:
        if jax.process_index() != 0:
            return
        name = f"step_{step:09d}"
        tmp = os.path.join(self.directory, f".tmp_{name}")
        final = os.path.join(self.directory, name)
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        arrays = {k: v for k, v in leaves}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "keys": [k for k, _ in leaves],
            "shapes": {k: list(v.shape) for k, v in leaves},
            "dtypes": {k: str(v.dtype) for k, v in leaves},
            "fingerprint": fingerprint,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        # LATEST pointer, also via atomic rename
        latest_tmp = os.path.join(self.directory, ".LATEST_tmp")
        with open(latest_tmp, "w") as f:
            f.write(name)
            f.flush()
            os.fsync(f.fileno())
        os.rename(latest_tmp, os.path.join(self.directory, "LATEST"))
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.cfg.keep_last] if self.cfg.keep_last else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)

    def wait(self) -> None:
        with self._lock:
            pending, self._pending = self._pending, []
        for t in pending:
            t.join()

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_"):
                out.append(int(d[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.directory, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return int(f.read().strip()[len("step_"):])

    def restore(self, like_state, step: Optional[int] = None,
                shardings=None, expected_fingerprint: str = ""
                ) -> Tuple[Any, Dict[str, Any]]:
        """Restore into the structure of ``like_state``.

        ``shardings``: optional matching pytree of NamedSharding — leaves are
        device_put with it (how a restored state re-enters the mesh).
        Returns (state, extra).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.directory}")
        base = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(base, "manifest.json")) as f:
            manifest = json.load(f)
        if expected_fingerprint and manifest["fingerprint"] and \
                manifest["fingerprint"] != expected_fingerprint:
            raise ValueError(
                f"checkpoint fingerprint {manifest['fingerprint']} does not "
                f"match config fingerprint {expected_fingerprint}")
        with np.load(os.path.join(base, "arrays.npz")) as npz:
            arrays = {k: npz[k] for k in npz.files}

        flat = _flatten_with_paths(like_state)
        missing = [k for k, _ in flat if k not in arrays]
        if missing:
            raise KeyError(f"checkpoint missing keys: {missing[:5]}...")
        leaves = [arrays[k] for k, _ in flat]
        treedef = jax.tree.structure(like_state)
        state = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else jax.numpy.asarray(x),
                state, shardings,
                is_leaf=lambda x: x is None or isinstance(x, np.ndarray))
        else:
            state = jax.tree.map(jax.numpy.asarray, state)
        return state, manifest.get("extra", {})
