from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.elastic import rescale_replicated_state

__all__ = ["CheckpointManager", "rescale_replicated_state"]
