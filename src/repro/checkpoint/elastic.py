"""Elastic mesh reshaping for local-SGD (MSF) replica state.

Local-SGD replicas are *designed* to diverge between syncs, which makes
elastic resize natural under the paper's averaging semantics:

* **shrink** (K → K' < K replicas): average the K replicas (exactly the
  paper's model synchronization), then keep/broadcast K' copies.
* **grow** (K → K' > K): average, then broadcast to all K' replicas —
  equivalent to a sync point followed by fan-out.

The replica dimension is the leading axis of every leaf (the layout the
local-SGD trainer uses under its pod-axis shard_map). States without a
replica dim (plain DDP) pass through unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rescale_replicated_state(state, old_replicas: int, new_replicas: int):
    """Reshape a replica-leading state pytree from K to K' replicas."""
    if old_replicas == new_replicas:
        return state

    def leaf(x):
        if x.ndim == 0 or x.shape[0] != old_replicas:
            # scalar counters etc. — replicated, leave as-is
            return x
        avg = jnp.mean(x.astype(jnp.float32), axis=0)
        out = jnp.broadcast_to(avg, (new_replicas,) + avg.shape)
        return out.astype(x.dtype)

    return jax.tree.map(leaf, state)


def add_replica_dim(state, replicas: int):
    """Fan a replica-free state out to K identical replicas (join a sync)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (replicas,) + x.shape), state)


def drop_replica_dim(state):
    """Average away the replica dim (final sync before export/eval)."""
    return jax.tree.map(
        lambda x: jnp.mean(x.astype(jnp.float32), axis=0).astype(x.dtype)
        if x.ndim > 0 else x, state)
