"""Process-wide tracing flags.

``unroll_scans()`` — when true, every ``lax.scan`` in the model/trainer code
unrolls fully. The dry-run sets this (env ``REPRO_UNROLL_SCANS=1``) because
XLA's ``cost_analysis()`` counts a while-loop body ONCE regardless of trip
count; with unrolled scans the FLOPs/bytes/collective counts in the
roofline table are exact. Normal training/serving keeps scans rolled
(compact HLO, fast compile).
"""
from __future__ import annotations

import os

_FORCE: bool | None = None


def set_unroll_scans(value: bool | None) -> None:
    global _FORCE
    _FORCE = value


def unroll_scans() -> bool:
    if _FORCE is not None:
        return _FORCE
    return os.environ.get("REPRO_UNROLL_SCANS", "0") == "1"


def scan_unroll_arg() -> bool | int:
    """Value to pass as ``lax.scan(..., unroll=)``."""
    return True if unroll_scans() else 1
