"""H-ladder runtime: mid-run adaptive MSF with zero recompiles.

The adaptive controller (PR 3) could only *recommend* an H for the next
launch: changing ``sync.period`` mid-run retraced and recompiled the train
block, so one long run could not traverse the paper's Figs 13-15 frontier
online. This module closes that gap:

* :func:`compile_rungs` AOT-compiles ("ladder warmup") ONE jitted train
  block for a geometric ladder of periods ``SyncConfig.ladder_rungs()``.
  The block body is H-independent -- the ``lax.scan`` over microbatches is
  driven by the batch's leading dim -- and the sync-state layout is
  H-independent too, so every rung shares one traced signature and one
  state pytree; only the compiled executable differs (batch shape
  ``(H, B, ...)``). ``jitted.lower(...).compile()`` pins each rung to a
  concrete executable: calling one can never retrace or recompile (a
  shape mismatch raises instead).

* :class:`LadderRuntime` holds the compiled rungs, the AOT-compiled
  switch transform (:func:`repro.core.local_sgd.ladder_switch_state` --
  flush the sync state to the fully synchronized model + restart the
  schedule counters), and the :class:`repro.core.autotune
  .AdaptiveController` in ladder mode. A controller move mid-run is then
  (a) one compiled switch call at the sync boundary and (b) picking a
  different already-compiled callable -- the driver also re-blocks the
  data pipeline at the new H. The switch is *exact*: bit-identical to
  launching fresh at the new H from the flushed model.

* :class:`CompileCounter` listens on jax's monitoring stream for backend
  compiles -- the hook CI's ``adaptive-smoke`` job uses to assert that
  after ladder warmup the whole adaptive run (blocks, switches,
  checkpoints) performs ZERO XLA compiles. Host-side block assembly must
  therefore stay numpy-only (see ``DataPipeline.next_host``): any stray
  eager jnp op would compile on first use and trip the assertion.

The runtime is deliberately host-driven and framework-level: it knows
nothing about the model, only about (state, batch) callables -- the SVM
path gets the same treatment from :func:`repro.core.svm.dms_block_ladder`.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import jax


class CompileCounter:
    """Counts XLA backend compiles via ``jax.monitoring`` duration events.

    ``mark()`` snapshots the count after ladder warmup;
    ``since_mark`` is the number CI asserts to be zero. Listener
    registration is process-global and cannot be undone on this jax
    version, so install one counter per process (``install`` is
    idempotent per instance).
    """

    EVENT = "/jax/core/compile/backend_compile_duration"

    def __init__(self):
        self.count = 0
        self.marked = 0
        self._installed = False

    def install(self) -> "CompileCounter":
        if not self._installed:
            jax.monitoring.register_event_duration_secs_listener(self._on)
            self._installed = True
        return self

    def _on(self, name: str, _duration: float, **_kw) -> None:
        if name == self.EVENT:
            self.count += 1

    def mark(self) -> None:
        self.marked = self.count

    @property
    def since_mark(self) -> int:
        return self.count - self.marked


def _avals(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def compile_rungs(jitted_step: Callable, state, sample_batch,
                  rungs) -> Dict[int, Callable]:
    """AOT-compile ``jitted_step`` for every rung's block shape.

    ``sample_batch`` is ONE microbatch (host numpy or jax leaves); rung H
    compiles for batch leaves ``(H,) + leaf.shape``. Returns
    ``{H: compiled}`` -- compiled executables raise on any other shape
    rather than recompiling, which is what makes the zero-recompile
    property enforceable by construction.
    """
    state_avals = _avals(state)
    out: Dict[int, Callable] = {}
    for h in sorted(set(int(r) for r in rungs)):
        batch_avals = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((h,) + tuple(x.shape), x.dtype),
            sample_batch)
        out[h] = jitted_step.lower(state_avals, batch_avals).compile()
    return out


class LadderRuntime:
    """Pre-compiled H ladder + adaptive controller, driven per block.

    The step runner calls :attr:`step_fn` for each block and
    :meth:`on_block` after it; a controller rung move applies the
    compiled switch and the runner re-blocks the data pipeline
    (:attr:`h` is the authoritative current rung). ``trajectory`` records
    every ``(block, H)`` transition including the start -- the artifact
    the CI job uploads.
    """

    def __init__(self, rungs: Dict[int, Callable], switch_fn: Callable,
                 controller, telemetry=None, shardings=None,
                 compile_counter: Optional[CompileCounter] = None):
        if controller.h not in rungs:
            raise ValueError(
                f"controller start rung {controller.h} not in compiled "
                f"ladder {sorted(rungs)}")
        self.rungs = dict(rungs)
        self.switch_fn = switch_fn
        self.controller = controller
        self.telemetry = telemetry
        self.shardings = shardings
        self.compile_counter = compile_counter
        self.blocks = 0
        self.switches = 0
        self.trajectory: List[Tuple[int, int]] = [(0, controller.h)]

    @property
    def h(self) -> int:
        return self.controller.h

    @property
    def step_fn(self) -> Callable:
        return self.rungs[self.h]

    def on_block(self, state):
        """One executed block: feed the controller, maybe switch rungs.

        Returns ``(state, switched)`` -- on a switch the state has been
        flushed/re-seeded by the compiled switch transform and the caller
        must re-block its data pipeline at the new :attr:`h`.
        """
        self.blocks += 1
        h_prev = self.controller.h
        # timing already landed in the shared telemetry via the per-rung
        # timed wrappers; this only advances the re-solve cadence
        self.controller.observe_block()
        if self.controller.h != h_prev:
            state = self.switch_fn(state)
            self.switches += 1
            self.trajectory.append((self.blocks, self.controller.h))
            return state, True
        return state, False

    # ------------------------------------------------------- checkpointing
    def checkpoint_state(self) -> dict:
        """The rung the checkpoint must restore (controller telemetry is
        deliberately not persisted -- it re-warms within adapt_every
        blocks)."""
        return {"h": self.h, "blocks": self.blocks}

    def restore(self, ck: dict) -> None:
        h = int(ck["h"])
        if h not in self.rungs:
            raise ValueError(
                f"checkpointed rung {h} not in compiled ladder "
                f"{sorted(self.rungs)}")
        # rewind the block counter to the checkpoint so replayed blocks
        # index the trajectory consistently with the run being resumed
        self.blocks = int(ck.get("blocks", self.blocks))
        if h != self.controller.h:
            self.controller.h = h
            self.controller.history.append((self.controller._blocks, h))
            self.trajectory.append((self.blocks, h))

    def place(self, state):
        """Re-enter restored (host) state into the mesh layout the
        compiled rungs expect."""
        if self.shardings is None:
            return state
        return jax.tree.map(jax.device_put, state, self.shardings)

    def to_dict(self) -> dict:
        out = {
            "ladder": sorted(self.rungs),
            "h": self.h,
            "blocks": self.blocks,
            "switches": self.switches,
            "h_trajectory": [list(t) for t in self.trajectory],
        }
        if self.compile_counter is not None:
            out["compiles_total"] = self.compile_counter.count
            out["compiles_after_warmup"] = self.compile_counter.since_mark
        if self.telemetry is not None:
            out["telemetry"] = self.telemetry.to_dict()
        return out
