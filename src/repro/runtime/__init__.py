from repro.runtime.ft import StepRunner, StragglerWatchdog, FaultInjector

__all__ = ["StepRunner", "StragglerWatchdog", "FaultInjector"]
