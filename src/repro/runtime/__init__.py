from repro.runtime.ft import StepRunner, StragglerWatchdog, FaultInjector
from repro.runtime.ladder import (CompileCounter, LadderRuntime,
                                  compile_rungs)

__all__ = ["StepRunner", "StragglerWatchdog", "FaultInjector",
           "CompileCounter", "LadderRuntime", "compile_rungs"]
