"""Fault-tolerant step runner: the control plane a real cluster drives.

Components:

* :class:`StragglerWatchdog` — per-step deadline timer. On a real pod this
  marks the step (and host) as straggling so the coordinator can trigger
  preemption-aware checkpointing or task re-slicing; here it records the
  event and (optionally) raises, which exercises the same restart path.
* :class:`FaultInjector` — deterministic failure/straggle injection for
  tests (``inject_failure_at`` step raises ``SimulatedFault``).
* :class:`StepRunner` — drives ``step_fn`` with checkpoint/restart:
  on failure, restores the latest checkpoint (params/opt/data cursor) and
  replays. ``max_restarts`` bounds the retry loop. Because batches are
  deterministic in (seed, step), replay is bitwise-consistent with a run
  that never failed — asserted in tests.

The runner is deliberately synchronous/CPU-testable; on a real deployment
the same loop runs unmodified per-controller, with the watchdog fed from
device heartbeats instead of wall-clock.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List

import jax

from repro.config.base import FaultToleranceConfig


class SimulatedFault(RuntimeError):
    pass


class StragglerWatchdog:
    def __init__(self, deadline_sec: float):
        self.deadline = deadline_sec
        self.events: List[Dict[str, Any]] = []

    def check(self, step: int, elapsed: float) -> bool:
        """Record and report whether the step straggled."""
        if self.deadline and elapsed > self.deadline:
            self.events.append({"step": step, "elapsed": elapsed})
            return True
        return False


class FaultInjector:
    def __init__(self, cfg: FaultToleranceConfig):
        self.cfg = cfg
        self._fired = False

    def before_step(self, step: int) -> None:
        if self.cfg.inject_straggle_sec and step == max(0, self.cfg.inject_failure_at - 1):
            time.sleep(self.cfg.inject_straggle_sec)
        if step == self.cfg.inject_failure_at and not self._fired:
            self._fired = True          # fail exactly once, then recover
            raise SimulatedFault(f"injected fault at step {step}")


class StepRunner:
    """Checkpoint/restart training driver.

    ``step_fn(state, batch) -> (state, metrics)`` must be pure (jitted).
    ``make_pipeline(start_step) -> iterator`` rebuilds the data pipeline at a
    cursor — the restore path uses it to resume data exactly where the
    checkpoint was taken.
    """

    def __init__(self, step_fn: Callable, ckpt_manager, fault_cfg: FaultToleranceConfig,
                 ckpt_interval: int, make_pipeline: Callable[[int], Any],
                 fingerprint: str = "", ladder=None):
        self.step_fn = step_fn
        self.ckpt = ckpt_manager
        self.cfg = fault_cfg
        self.interval = max(1, ckpt_interval)
        self.make_pipeline = make_pipeline
        self.fingerprint = fingerprint
        # optional H-ladder runtime (repro.runtime.ladder.LadderRuntime):
        # when set, each step is one sync block executed by the ladder's
        # current pre-compiled rung; after the block the controller may
        # switch rungs, in which case the (flushed) state continues under
        # the new compiled callable and the data pipeline is re-blocked
        # at the new H from its current cursor — no recompilation.
        self.ladder = ladder
        self.watchdog = StragglerWatchdog(fault_cfg.step_deadline_sec)
        self.injector = FaultInjector(fault_cfg)
        self.restarts = 0
        self.metrics_log: List[Dict[str, Any]] = []

    def run(self, state, start_step: int, num_steps: int):
        step = start_step
        pipeline = self.make_pipeline(step)
        end = start_step + num_steps
        while step < end:
            try:
                state, step, pipeline = self._run_until(state, step, end, pipeline)
            except SimulatedFault:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                state, step, pipeline = self._restore(state)
        return state, step

    def _run_until(self, state, step: int, end: int, pipeline):
        while step < end:
            try:
                batch = next(pipeline)
            except StopIteration:
                break
            self.injector.before_step(step)
            step_fn = (self.ladder.step_fn if self.ladder is not None
                       else self.step_fn)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(jax.tree.leaves(metrics))
            elapsed = time.perf_counter() - t0
            straggled = self.watchdog.check(step, elapsed)
            self.metrics_log.append(
                {"step": step, "elapsed": elapsed, "straggled": straggled,
                 **{k: float(v) for k, v in metrics.items()}})
            step += 1
            if step % self.interval == 0:
                extra = {"data": pipeline.state()}
                if self.ladder is not None:
                    extra["ladder"] = self.ladder.checkpoint_state()
                self.ckpt.save(step, state, extra=extra,
                               fingerprint=self.fingerprint)
            if self.ladder is not None:
                state, switched = self.ladder.on_block(state)
                if switched:
                    # same microbatch stream, re-blocked at the new H
                    pipeline = self.make_pipeline(pipeline.state()["step"])
        return state, step, pipeline

    def _restore(self, like_state):
        self.ckpt.wait()
        latest = self.ckpt.latest_step()
        if latest is None:
            # no checkpoint yet — restart from scratch
            return like_state, 0, self.make_pipeline(0)
        state, extra = self.ckpt.restore(
            like_state, expected_fingerprint=self.fingerprint)
        cursor = int(extra.get("data", {}).get("step", latest))
        if self.ladder is not None:
            if "ladder" in extra:
                self.ladder.restore(extra["ladder"])
            state = self.ladder.place(state)
        return state, latest, self.make_pipeline(cursor)
