"""Architecture registry. ``repro/configs/*.py`` register themselves here."""
from __future__ import annotations

from typing import Callable, Dict

from repro.config.base import ModelConfig

_ARCHS: Dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: Dict[str, Callable[[], ModelConfig]] = {}


def register_arch(arch_id: str, full: Callable[[], ModelConfig],
                  smoke: Callable[[], ModelConfig]) -> None:
    _ARCHS[arch_id] = full
    _SMOKE[arch_id] = smoke


def get_arch(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    if arch_id not in _ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCHS)}")
    return _ARCHS[arch_id]()


def get_smoke(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    return _SMOKE[arch_id]()


def list_archs():
    _ensure_loaded()
    return sorted(_ARCHS)


def _ensure_loaded() -> None:
    if _ARCHS:
        return
    import repro.configs  # noqa: F401  (imports register every arch)
