"""Config system: typed dataclasses + dict/CLI overrides.

Everything the launcher, dry-run and tests consume is one of these configs.
No external deps (no hydra/omegaconf) — overrides are ``key.subkey=value``
strings parsed by :mod:`repro.config.cli`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block config (dense-routing einsum formulation)."""

    num_experts: int = 0           # 0 => dense FFN
    top_k: int = 2
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block config."""

    state_dim: int = 128           # N — SSM state size per head
    head_dim: int = 64             # P — channels per SSD head
    expand: int = 2                # d_inner = expand * d_model
    chunk_size: int = 256          # SSD chunk length
    conv_width: int = 4


@dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description covering every assigned family."""

    name: str = "unnamed"
    family: str = "dense"          # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 512
    vocab_size: int = 1024
    head_dim: int = 0              # 0 => d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    norm_type: str = "rms"         # rms | layer (whisper)
    tie_embeddings: bool = False
    # seq-chunked cross-entropy: cap the materialized logits to
    # (B, ce_chunk, V) per scan step (0 ⇒ unchunked). Vital for
    # 150k-vocab archs at 32k seq.
    ce_chunk: int = 0
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    # hybrid (zamba2-style): a shared attention+MLP block applied every
    # `shared_block_every` backbone layers.
    shared_block_every: int = 0
    # enc-dec (whisper-style)
    n_encoder_layers: int = 0
    # stubbed audio frontend: number of precomputed frame embeddings the
    # encoder consumes (whisper: 1500 = 30 s at 50 Hz post-conv)
    n_audio_frames: int = 0
    # vlm (paligemma-style): number of image-prefix positions provided by the
    # (stubbed) vision frontend.
    num_image_tokens: int = 0
    # long-context capability flag: sub-quadratic step cost in seq_len.
    subquadratic: bool = False
    dtype: str = "bfloat16"        # activation/computation dtype
    param_dtype: str = "float32"   # master parameter dtype

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.moe.num_experts > 0

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs and roofline)."""
        from repro.models.registry import analytic_param_count

        return analytic_param_count(self)

    def active_param_count(self) -> int:
        from repro.models.registry import analytic_param_count

        return analytic_param_count(self, active_only=True)


@dataclass(frozen=True)
class MeshConfig:
    """Logical device mesh. ``axis_names`` order is major→minor."""

    shape: Tuple[int, ...] = (1,)
    axis_names: Tuple[str, ...] = ("data",)
    # which mesh axis carries each parallelism role
    data_axis: str = "data"        # batch / FSDP axis
    model_axis: str = "model"      # TP / EP / SP axis
    replica_axis: str = ""         # local-SGD (MSF) replica axis; "" => none

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    def axis_size(self, name: str) -> int:
        if not name or name not in self.axis_names:
            return 1
        return self.shape[self.axis_names.index(name)]


@dataclass(frozen=True)
class SyncConfig:
    """The paper's contribution as config: model-synchronization schedule.

    ``strategy``:
      * ``"sync_every_step"`` — canonical DDP (paper's MSF=1 analog).
      * ``"periodic"``        — H local steps between parameter averages
                                (paper's DMS / local SGD). ``period=H``.
      * ``"hierarchical"``    — every-step sync on the data axis, periodic
                                sync on the replica (pod) axis.

    ``overlap`` — how the residual sync cost is taken off the critical path:
      * ``"none"``    — blocking collective at the block boundary (paper).
      * ``"delayed"`` — stale-by-one averaging: block *i*'s averaged delta is
                        applied at the end of block *i+1*, so the collective
                        overlaps block *i+1*'s compute (Stich 2018 local-SGD
                        staleness regime).
      * ``"chunked"`` — round-robin the parameter tree into ``chunks`` shards
                        and sync one shard per block: each leaf syncs every
                        ``chunks·period`` steps and per-sync wire bytes shrink
                        ``chunks``×.

    ``topology`` — which replicas a sync point couples:
      * ``"all"``      — global collective (pmean/psum/all-gather); one
                         straggler stalls every replica.
      * ``"ring"``     — each replica averages with its two ``ppermute``
                         neighbors (mixing weight 1/3 each); O(1) neighbor
                         bytes per sync, no global barrier.
      * ``"pairwise"`` — rotating disjoint pairs (odd–even pairing by sync
                         round) average with weight 1/2; needs an even
                         replica count. Gossip reaches consensus only
                         geometrically (factor λ₂ per round — see
                         :func:`repro.core.costmodel.gossip_lambda2`), so the
                         auto-tuner caps H tighter for sparse topologies.
    """

    strategy: str = "sync_every_step"
    period: int = 1                # H — data points/steps per sync (block size)
    compression: str = "none"      # none | int8
    error_feedback: bool = True    # residual accumulation for compression
    slowmo: float = 0.0            # outer momentum on sync delta (0 => off)
    slowmo_lr: float = 1.0
    eval_at_sync: bool = False     # paper's per-sync CV-accuracy computation
    overlap: str = "none"          # none | delayed | chunked
    chunks: int = 4                # R — shard count for overlap="chunked"
    topology: str = "all"          # all | ring | pairwise (gossip)
    # Asynchronous (unsynchronized-round) gossip: each replica mixes with
    # the *last received* neighbor model instead of the current-round one —
    # a double-buffered ppermute exchange (send this boundary, consume at
    # the next, bounded staleness = 1 round on the compiled path). Requires
    # a gossip topology; the exchange is already a full block off the
    # critical path, so overlap modes are rejected (they would compound the
    # staleness past the 1-round bound). The auto-tuner caps H by the
    # staleness-aware effective spectral gap
    # (:func:`repro.core.costmodel.effective_spectral_gap`).
    gossip_async: bool = False
    # --- adaptive MSF (repro.core.autotune.AdaptiveController) ---------
    # When ``adaptive`` is on, the training driver re-solves the period
    # online from measured T_step/T_sync every ``adapt_every`` blocks
    # (``period`` is the starting H). ``adapt_hysteresis`` is the relative
    # change required before H actually moves (every move recompiles the
    # train block); target/drift mirror choose_period's knobs.
    adaptive: bool = False
    adapt_every: int = 16          # R — blocks between controller re-solves
    adapt_hysteresis: float = 0.25
    adapt_target_overhead: float = 0.05
    adapt_max_drift: float = 0.01
    # --- H-ladder runtime (repro.runtime.ladder.LadderRuntime) ---------
    # The live trainer pre-compiles the train block for a *ladder* of
    # periods sharing one state layout, so an adaptive H move mid-run is
    # a flush + pick-another-compiled-callable — no recompilation. The
    # ladder is geometric {1, ladder_base, ladder_base², …, adapt_h_max}
    # (plus ``period`` so the starting rung always exists) unless
    # ``adapt_ladder`` pins explicit rungs. ``adapt_rung_hysteresis`` is
    # the controller's move threshold in *rung units*: the re-solved H
    # must snap at least that many rungs away before the schedule moves
    # (geometric spacing already absorbs sub-factor-of-base noise).
    adapt_h_max: int = 64          # top rung of the geometric ladder
    adapt_ladder: Tuple[int, ...] = ()   # explicit rungs (overrides h_max)
    ladder_base: int = 2           # geometric ladder ratio
    adapt_rung_hysteresis: int = 1

    def ladder_rungs(self) -> Tuple[int, ...]:
        """The pre-compiled H ladder: sorted, unique, start rung included."""
        if self.adapt_ladder:
            rungs = set(int(h) for h in self.adapt_ladder)
        else:
            rungs, h = set(), 1
            while h <= max(1, self.adapt_h_max):
                rungs.add(h)
                h *= max(2, self.ladder_base)
        rungs.add(max(1, self.period))
        return tuple(sorted(rungs))

    @property
    def msf_label(self) -> str:
        tail = "" if self.overlap == "none" else f",overlap={self.overlap}"
        if self.topology != "all":
            tail += f",topo={self.topology}"
        if self.gossip_async:
            tail += ",async"
        if self.adaptive:
            tail += ",adaptive"
        return f"{self.strategy}(H={self.period},comp={self.compression}{tail})"


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "sgd"              # sgd | momentum | adamw
    learning_rate: float = 1e-3
    schedule: str = "constant"     # constant | paper_inverse | cosine
    warmup_steps: int = 0
    total_steps: int = 1000
    momentum: float = 0.9
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 0.0         # 0 => off
    # dtype of adam/momentum moments. bf16 halves optimizer-state HBM —
    # how the 235B config fits a single v5e pod (Gopher-style bf16 stats).
    moment_dtype: str = "float32"


@dataclass(frozen=True)
class DataConfig:
    dataset: str = "synthetic_lm"  # synthetic_lm | ijcnn1 | webspam | epsilon
    seq_len: int = 1024
    global_batch: int = 8
    seed: int = 0
    num_samples: int = 0           # 0 => dataset default
    features: int = 0
    sparsity: float = 0.0


@dataclass(frozen=True)
class CheckpointConfig:
    directory: str = "/tmp/repro_ckpt"
    interval_steps: int = 100
    keep_last: int = 3
    async_write: bool = False


@dataclass(frozen=True)
class FaultToleranceConfig:
    step_deadline_sec: float = 0.0   # 0 => no straggler watchdog
    max_restarts: int = 3
    inject_failure_at: int = -1      # test hook: raise at this step
    inject_straggle_sec: float = 0.0


@dataclass(frozen=True)
class TrainConfig:
    """Top-level experiment config."""

    model: ModelConfig = field(default_factory=ModelConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)
    sync: SyncConfig = field(default_factory=SyncConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    data: DataConfig = field(default_factory=DataConfig)
    checkpoint: CheckpointConfig = field(default_factory=CheckpointConfig)
    fault: FaultToleranceConfig = field(default_factory=FaultToleranceConfig)
    steps: int = 100
    log_every: int = 10
    remat: str = "none"            # none | full | dots  (activation ckpt policy)
    scan_layers: bool = True       # lax.scan over layer stack
    seed: int = 0


def replace(cfg, **kw):
    """``dataclasses.replace`` that also accepts dotted keys, e.g.
    ``replace(cfg, **{"sync.period": 32})``."""
    direct = {k: v for k, v in kw.items() if "." not in k}
    nested: dict = {}
    for k, v in kw.items():
        if "." in k:
            head, rest = k.split(".", 1)
            nested.setdefault(head, {})[rest] = v
    for head, sub in nested.items():
        direct[head] = replace(getattr(cfg, head), **sub)
    return dataclasses.replace(cfg, **direct)


def asdict(cfg) -> dict:
    return dataclasses.asdict(cfg)


def config_fingerprint(cfg) -> str:
    """Stable hash for checkpoint compatibility checks."""
    import hashlib
    import json

    blob = json.dumps(asdict(cfg), sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()[:16]
