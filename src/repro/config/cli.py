"""Tiny CLI override layer: ``--arch qwen2.5-3b --set sync.period=32``."""
from __future__ import annotations

import argparse
from typing import Any, Sequence

from repro.config.base import TrainConfig, replace


def _coerce(value: str) -> Any:
    for cast in (int, float):
        try:
            return cast(value)
        except ValueError:
            pass
    if value.lower() in ("true", "false"):
        return value.lower() == "true"
    return value


def apply_overrides(cfg: TrainConfig, overrides: Sequence[str]) -> TrainConfig:
    kw = {}
    for item in overrides:
        if "=" not in item:
            raise ValueError(f"override must be key=value, got {item!r}")
        key, value = item.split("=", 1)
        kw[key] = _coerce(value)
    return replace(cfg, **kw) if kw else cfg


def build_parser(description: str) -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(description=description)
    p.add_argument("--arch", default="smollm-360m", help="architecture id")
    p.add_argument("--shape", default="train_4k",
                   help="input shape cell: train_4k|prefill_32k|decode_32k|long_500k|smoke")
    p.add_argument("--multi-pod", action="store_true", help="use the 2x16x16 mesh")
    p.add_argument("--set", dest="overrides", action="append", default=[],
                   metavar="KEY=VALUE", help="dotted config override")
    return p
