from repro.config.base import (
    CheckpointConfig,
    DataConfig,
    FaultToleranceConfig,
    MeshConfig,
    ModelConfig,
    MoEConfig,
    OptimizerConfig,
    SSMConfig,
    SyncConfig,
    TrainConfig,
    asdict,
    config_fingerprint,
    replace,
)
from repro.config.registry import get_arch, get_smoke, list_archs, register_arch

__all__ = [
    "CheckpointConfig", "DataConfig", "FaultToleranceConfig", "MeshConfig",
    "ModelConfig", "MoEConfig", "OptimizerConfig", "SSMConfig", "SyncConfig",
    "TrainConfig", "asdict", "config_fingerprint", "replace",
    "get_arch", "get_smoke", "list_archs", "register_arch",
]
