"""Public wrapper: padding/alignment glue around the hinge Pallas kernel.

Pads d to a lane multiple (128) and n to a block multiple. Padded rows get
y = 0 so their hinge contribution vanishes (y multiplies every term);
padded feature columns are zero in both X and w so they contribute nothing
to margins and stay zero in the gradient.

``interpret`` defaults to *auto*: compiled Pallas on TPU/GPU backends, the
interpreter only on CPU (where Pallas has no compiled lowering). The old
default of ``interpret=True`` everywhere meant ``grad_impl="pallas"`` ran
the interpreter even on accelerators — the hot path never compiled.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.hinge.kernel import hinge_block_grad_padded

_LANE = 128


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def default_interpret() -> bool:
    """Interpret only where Pallas cannot compile (CPU backends)."""
    return jax.default_backend() not in ("tpu", "gpu", "cuda", "rocm")


@functools.partial(jax.jit, static_argnames=("c", "block_n", "interpret"))
def hinge_block_grad(w: jax.Array, x: jax.Array, y: jax.Array, c: float = 1.0,
                     *, block_n: int = 0,
                     interpret: Optional[bool] = None) -> jax.Array:
    """Drop-in for :func:`repro.kernels.hinge.ref.hinge_block_grad`."""
    if interpret is None:
        interpret = default_interpret()
    n, d = x.shape
    dp = _round_up(d, _LANE)
    if block_n <= 0:
        # VMEM-guided default: ≤4 MiB X block, sublane (8) aligned
        block_n = max(8, min(512, _round_up(n, 8)))
    npad = _round_up(n, block_n)

    xp = jnp.zeros((npad, dp), x.dtype).at[:n, :d].set(x)
    wp = jnp.zeros((1, dp), w.dtype).at[0, :d].set(w)
    yp = jnp.zeros((1, npad), y.dtype).at[0, :n].set(y)

    out = hinge_block_grad_padded(wp, xp, yp, c_over_n=c / n, block_n=block_n,
                                  interpret=interpret)
    return out[0, :d]
