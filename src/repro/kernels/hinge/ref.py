"""Pure-jnp oracle for the hinge block-subgradient kernel."""
from __future__ import annotations

import jax


def hinge_block_grad(w: jax.Array, x: jax.Array, y: jax.Array,
                     c: float) -> jax.Array:
    """w: (d,) · x: (n, d) · y: (n,) → mean subgradient (d,)."""
    margins = 1.0 - y * (x @ w)
    viol = (margins > 0).astype(w.dtype)
    return w - c * ((viol * y) @ x) / x.shape[0]
