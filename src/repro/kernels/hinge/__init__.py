from repro.kernels.hinge import ops, ref

__all__ = ["ops", "ref"]
