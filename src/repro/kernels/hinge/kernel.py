"""Fused hinge block-subgradient Pallas kernel.

Computes, in one pass over the block,

    grad = w − (C/n)·Σᵢ 1{1 − yᵢ⟨xᵢ,w⟩ > 0}·yᵢ·xᵢ

which is the SVM inner loop (margins matvec + masked accumulation matvec)
fused so X is read from HBM exactly once. The grid walks row-blocks of X
sequentially (TPU grid order), accumulating the masked sum into the output
ref in VMEM; the final grid step folds in ``w`` and the ``C/n`` scale.

Tiling: X block = (block_n, d). d is padded to a lane multiple (128) by
``ops.py``; block_n is sublane-aligned (multiple of 8). For the paper's
largest dataset (Epsilon, d=2000→2048) a 512-row block is
512·2048·4B = 4 MiB of VMEM — inside the ~16 MiB v5e budget with headroom
for w, y and the accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hinge_kernel(c_over_n, w_ref, x_ref, y_ref, o_ref):
    i = pl.program_id(0)
    n_blocks = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[...]                       # (1, d)
    x = x_ref[...]                       # (bn, d)
    y = y_ref[...]                       # (1, bn)
    margins = 1.0 - y * jax.lax.dot_general(
        w, x, (((1,), (1,)), ((), ())))  # (1, bn) = w·xᵀ
    viol = jnp.where(margins > 0, y, 0.0)          # yᵢ where violated else 0
    # (1, bn) @ (bn, d) → (1, d) masked accumulation
    o_ref[...] += jax.lax.dot_general(viol, x, (((1,), (0,)), ((), ())))

    @pl.when(i == n_blocks - 1)
    def _finish():
        o_ref[...] = w - c_over_n * o_ref[...]


@functools.partial(jax.jit, static_argnames=("block_n", "c_over_n", "interpret"))
def hinge_block_grad_padded(w2: jax.Array, x: jax.Array, y2: jax.Array, *,
                            c_over_n: float, block_n: int,
                            interpret: bool = False) -> jax.Array:
    """w2: (1, d) · x: (n, d) · y2: (1, n), all padded/aligned. → (1, d)."""
    n, d = x.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_hinge_kernel, c_over_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),          # w: resident
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),    # X row-block
            pl.BlockSpec((1, block_n), lambda i: (0, i)),    # y row-block
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (0, 0)),    # accumulator
        out_shape=jax.ShapeDtypeStruct((1, d), x.dtype),
        interpret=interpret,
    )(w2, x, y2)
