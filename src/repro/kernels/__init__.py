"""Pallas TPU kernels for the framework's compute hot spots.

Each subpackage is ``kernel.py`` (``pl.pallas_call`` + explicit BlockSpec
VMEM tiling, TPU target), ``ops.py`` (jit'd public wrapper with padding /
layout glue and an ``interpret=`` switch), and ``ref.py`` (pure-jnp oracle
the tests sweep against).

The paper itself has no kernel-level contribution (its optimization is the
sync schedule); these kernels cover the substrate's hot spots:

* ``hinge``            — fused SVM block-subgradient (the paper's inner loop)
* ``flash_attention``  — tiled online-softmax attention (train/prefill)
* ``ssd``              — Mamba2 state-space-duality chunk scan
* ``quant``            — int8 pack/unpack for compressed MSF sync
"""
