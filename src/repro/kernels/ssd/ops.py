"""Public SSD wrapper: sequence padding + chunk-size selection.

Padding is safe because a padded step with Δ = 0 is the identity: the decay
``exp(0·A) = 1`` leaves the state untouched and the injected term is 0; the
padded y rows are sliced off.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_scan_padded


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, bm: jax.Array,
             cm: jax.Array, *, chunk: int = 128,
             interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Drop-in for :func:`repro.kernels.ssd.ref.ssd_scan` (zero init state)."""
    b, l, h, p = x.shape
    lp = _round_up(l, chunk)
    if lp != l:
        pad = [(0, 0), (0, lp - l)]
        x = jnp.pad(x, pad + [(0, 0), (0, 0)])
        dt = jnp.pad(dt, pad + [(0, 0)])
        bm = jnp.pad(bm, pad + [(0, 0)])
        cm = jnp.pad(cm, pad + [(0, 0)])
    y, sfin = ssd_scan_padded(x, dt, a, bm, cm, chunk=chunk,
                              interpret=interpret)
    return y[:, :l], sfin
