from repro.kernels.ssd import ops, ref

__all__ = ["ops", "ref"]
