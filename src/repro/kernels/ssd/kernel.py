"""Mamba2 SSD chunk-scan Pallas kernel (state-space duality).

The SSD insight: split L into chunks of Q steps. Within a chunk the output
is an attention-like quadratic form; across chunks only the (N×P) state
recurs. Per chunk (per batch b, head h):

    cum_t   = Σ_{s≤t} Δ_s·A                      (running log-decay)
    L_ts    = exp(cum_t − cum_s)·1{s ≤ t}        (decay kernel)
    Y_intra = ((C Bᵀ) ∘ L ∘ Δ) X                 (Q×Q quadratic, MXU)
    Y_inter = (C ∘ exp(cum)) S_prev              (Q×N @ N×P, MXU)
    S_next  = exp(cum_Q)·S_prev + (B ∘ Δ·exp(cum_Q − cum))ᵀ X

Grid = (batch, heads, num_chunks); chunks are the innermost (sequential)
dim, so the inter-chunk state lives in a (N, P) f32 VMEM scratch that
persists across chunk steps and resets at chunk 0 — the TPU-native
replacement for the GPU version's cross-block shared-memory staging.

Tiling: chunk block loads are (1, Q, 1, P) x / (1, Q, N) B,C. With
Q=128..256, N=128, P=64..128 everything is MXU-aligned and the VMEM
working set is ≤ ~1 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, sfin_ref,
                state_ref):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _reset():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (Q,)
    a = a_ref[0].astype(jnp.float32)               # scalar
    bm = b_ref[0].astype(jnp.float32)              # (Q, N)
    cm = c_ref[0].astype(jnp.float32)              # (Q, N)

    q = x.shape[0]
    da = dt * a                                    # (Q,)
    cum = jnp.cumsum(da)                           # (Q,) inclusive
    total = cum[-1]

    # intra-chunk quadratic term
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    # L_ts = exp(cum_t − cum_s) for s ≤ t (decay from step s+1 .. t);
    # mask before exp so the s > t entries can't overflow
    tri = cols <= rows
    ldec = jnp.exp(jnp.where(tri, cum[:, None] - cum[None, :], -60.0))
    ldec = jnp.where(tri, ldec, 0.0)
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())))  # (Q,Q)
    scores = scores * ldec * dt[None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())))    # (Q,P)

    # inter-chunk contribution from carried state
    s_prev = state_ref[...]                        # (N, P)
    c_scaled = cm * jnp.exp(cum)[:, None]          # (Q, N)
    y = y + jax.lax.dot_general(c_scaled, s_prev, (((1,), (0,)), ((), ())))

    # state update
    b_scaled = bm * (dt * jnp.exp(total - cum))[:, None]   # (Q, N)
    s_new = jnp.exp(total) * s_prev + jax.lax.dot_general(
        b_scaled, x, (((0,), (0,)), ((), ())))             # (N, P)
    state_ref[...] = s_new

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _final():
        sfin_ref[0, 0] = s_new.astype(sfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_padded(x: jax.Array, dt: jax.Array, a: jax.Array, bm: jax.Array,
                    cm: jax.Array, *, chunk: int, interpret: bool = False):
    """x: (B, L, H, P) · dt: (B, L, H) · a: (H,) · bm/cm: (B, L, N).

    L must be a multiple of ``chunk``. Returns (y, final_state (B,H,N,P)).
    """
    b, l, h, p = x.shape
    n = bm.shape[-1]
    assert l % chunk == 0
    grid = (b, h, l // chunk)

    y, sfin = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, chunk, n), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, ih, ic: (ib, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, n, p), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, bm, cm)
    return y, sfin
