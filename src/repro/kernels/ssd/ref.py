"""Oracle for the Mamba2 SSD chunk scan: the exact per-step recurrence.

Selective state space (per head, diagonal A):

    S_t = exp(Δ_t·A) · S_{t−1} + Δ_t · B_t xᵀ_t        S ∈ ℝ^{N×P}
    y_t = C_t · S_t                                     y ∈ ℝ^{P}

x: (B, L, H, P) · dt: (B, L, H) · A: (H,) (negative) · Bm/Cm: (B, L, N)
(single B/C group shared across heads, as in Mamba2). Returns
(y (B, L, H, P), final_state (B, H, N, P)).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, bm: jax.Array,
             cm: jax.Array, init_state: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, jax.Array]:
    b, l, h, p = x.shape
    n = bm.shape[-1]
    x32 = x.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    a32 = a.astype(jnp.float32)
    bm32 = bm.astype(jnp.float32)
    cm32 = cm.astype(jnp.float32)

    s0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(s, inputs):
        xt, dtt, bt, ct = inputs        # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * a32)      # (B,H)
        s = s * decay[:, :, None, None]
        s = s + (dtt[:, :, None, None] * bt[:, None, :, None]
                 * xt[:, :, None, :])   # (B,H,N,P)
        y = jnp.einsum("bn,bhnp->bhp", ct, s)
        return s, y

    xs = (jnp.moveaxis(x32, 1, 0), jnp.moveaxis(dt32, 1, 0),
          jnp.moveaxis(bm32, 1, 0), jnp.moveaxis(cm32, 1, 0))
    s_final, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1)          # (B, L, H, P)
    return y.astype(x.dtype), s_final
