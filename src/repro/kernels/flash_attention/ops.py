"""Public flash-attention wrapper: layout + padding glue.

Model code uses (B, S, H, dh) activations; the kernel wants (B, H, S, dh)
and block-aligned S / lane-aligned dh. Sequence padding is masked out by
causality for queries (extra rows are discarded) and by explicit key
validity for keys (padded keys land in masked-out positions only when the
caller guarantees ``sk`` alignment — ops pads ``sk`` and relies on the
causal/prefix mask plus a validity clamp).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_padded

_LANE = 128


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=(
    "causal", "prefix_len", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, prefix_len: int = 0,
                    block_q: int = 0, block_k: int = 0,
                    interpret: bool = True) -> jax.Array:
    """q: (B, S, H, dh) · k/v: (B, S, KV, dh) → (B, S, H, dh)."""
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]

    bq = block_q or min(512, _round_up(sq, 128))
    bk = block_k or min(512, _round_up(sk, 128))
    sqp = _round_up(sq, bq)
    skp = _round_up(sk, bk)
    dhp = _round_up(dh, _LANE)

    qt = jnp.zeros((b, h, sqp, dhp), q.dtype).at[:, :, :sq, :dh].set(
        q.transpose(0, 2, 1, 3))
    kt = jnp.zeros((b, kvh, skp, dhp), k.dtype).at[:, :, :sk, :dh].set(
        k.transpose(0, 2, 1, 3))
    vt = jnp.zeros((b, kvh, skp, dhp), v.dtype).at[:, :, :sk, :dh].set(
        v.transpose(0, 2, 1, 3))
    if skp != sk and not causal:
        # full attention with padded keys: restrict to the valid prefix
        # (kernel's non-causal prefix mode masks cols ≥ prefix_len)
        prefix_len = sk
    out = flash_attention_padded(qt, kt, vt, causal=causal,
                                 prefix_len=prefix_len, block_q=bq,
                                 block_k=bk, sm_scale=1.0 / (dh ** 0.5),
                                 interpret=interpret)
    return out[:, :, :sq, :dh].transpose(0, 2, 1, 3)
