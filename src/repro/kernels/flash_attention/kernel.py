"""Flash attention (forward) Pallas kernel, GQA-aware, causal/prefix masks.

Grid = (batch, q_heads, num_q_blocks, num_kv_blocks); the kv dim is the
innermost (sequential on TPU), so the online-softmax running state —
``acc (bq, dh)``, ``m/l (bq, 128)`` — lives in VMEM scratch that persists
across kv steps and is reset when ``ik == 0``.

GQA without materializing repeated KV: the K/V BlockSpec index maps divide
the query-head grid index by the group size, so each query head streams its
*shared* KV head straight from HBM — no gather, no expanded copy.

Tiling (v5e): q block (1,1,bq,dh), kv block (1,1,bk,dh) with bq=bk=512,
dh ≤ 256 ⇒ ~2·512·256·4B = 1 MiB resident + scratch; MXU-aligned since
bq/bk/dh are multiples of 128 (dh padded by ops.py when needed).

Causality is exploited at *block* granularity: fully-masked kv blocks are
skipped via ``pl.when`` (half the FLOPs of a naive masked sweep at long
seq; the roofline compute term of train cells counts this).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _fa_kernel(group: int, causal: bool, prefix_len: int, scale: float,
               q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)
    bq = q_ref.shape[2]
    bk = k_ref.shape[2]

    @pl.when(ik == 0)
    def _reset():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * bq
    k_start = ik * bk

    # block-level causal skip: this kv block attends nothing when its first
    # key is beyond the last query of the block AND it is not prefix-visible
    live = True
    if causal:
        live = (k_start <= q_start + bq - 1) | (k_start < prefix_len)
    elif prefix_len:
        # full attention over the first prefix_len (valid) keys only
        live = k_start < prefix_len

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = (cols <= rows) | (cols < prefix_len)
            s = jnp.where(mask, s, _NEG_INF)
        elif prefix_len:
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(cols < prefix_len, s, _NEG_INF)

        m_prev = m_ref[...]                           # (bq, 128) broadcast col
        m_cur = jnp.max(s, axis=-1, keepdims=True)    # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)            # (bq, 128)
        p = jnp.exp(s - m_new[:, :1])                 # (bq, bk)
        corr = jnp.exp(m_prev[:, :1] - m_new[:, :1])  # (bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _write():
        l = l_ref[:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "prefix_len", "block_q", "block_k", "sm_scale", "interpret"))
def flash_attention_padded(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool, prefix_len: int, block_q: int,
                           block_k: int, sm_scale: float = 0.0,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, dh) · k/v: (B, KV, Sk, dh), aligned shapes. → like q.

    ``sm_scale`` must be 1/√(unpadded dh) when dh was zero-padded.
    """
    b, h, sq, dh = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    assert h % kvh == 0
    group = h // kvh
    assert sq % block_q == 0 and sk % block_k == 0
    grid = (b, h, sq // block_q, sk // block_k)
    scale = sm_scale or 1.0 / (dh ** 0.5)

    kernel = functools.partial(_fa_kernel, group, causal, prefix_len, scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),    # acc
            pltpu.VMEM((block_q, 128), jnp.float32),   # m (running max)
            pltpu.VMEM((block_q, 128), jnp.float32),   # l (running denom)
        ],
        interpret=interpret,
    )(q, k, v)
