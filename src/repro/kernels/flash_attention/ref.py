"""Pure-jnp oracle: grouped-query SDPA with f32 softmax."""
from __future__ import annotations


import jax
import jax.numpy as jnp


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, prefix_len: int = 0) -> jax.Array:
    """q: (B, H, Sq, dh) · k/v: (B, KV, Sk, dh) → (B, H, Sq, dh)."""
    b, h, sq, dh = q.shape
    kv, sk = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, sq, dh)
    s = jnp.einsum("bkgqd,bktd->bkgqt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / (dh ** 0.5)
    if causal:
        rows = jnp.arange(sq)[:, None]
        cols = jnp.arange(sk)[None, :]
        mask = (cols <= rows) | (cols < prefix_len)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,bktd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, dh).astype(q.dtype)
