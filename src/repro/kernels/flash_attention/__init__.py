from repro.kernels.flash_attention import ops, ref

__all__ = ["ops", "ref"]
