"""int8 pack/unpack Pallas kernels for compressed MSF sync.

The quantize pass is pure bandwidth: read fp32, write int8 (4× fewer output
bytes). One grid step processes a (block_m, 128)-lane tile. The per-tensor
scale is a (1, 1) scalar operand resident in SMEM-like VMEM for the whole
sweep; computing the global amax is a cheap jnp reduction in ``ops.py``
(fusing it here would force a second pass anyway).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, scale_ref, q_ref):
    inv = 1.0 / scale_ref[0, 0]
    q = jnp.round(x_ref[...] * inv)
    q_ref[...] = jnp.clip(q, -127.0, 127.0).astype(jnp.int8)


def _dequant_kernel(q_ref, scale_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * scale_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def quantize_padded(x: jax.Array, scale: jax.Array, *, block_m: int,
                    interpret: bool = False) -> jax.Array:
    """x: (m, 128) fp32, scale: (1, 1) → int8 (m, 128)."""
    m, lanes = x.shape
    assert m % block_m == 0
    return pl.pallas_call(
        _quant_kernel,
        grid=(m // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, lanes), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, lanes), jnp.int8),
        interpret=interpret,
    )(x, scale)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def dequantize_padded(q: jax.Array, scale: jax.Array, *, block_m: int,
                      interpret: bool = False) -> jax.Array:
    m, lanes = q.shape
    assert m % block_m == 0
    return pl.pallas_call(
        _dequant_kernel,
        grid=(m // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, lanes), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q.shape[0], lanes), jnp.float32),
        interpret=interpret,
    )(q, scale)
