"""Shape-agnostic wrappers: flatten → (m, 128) lane tiles → kernel."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.quant.kernel import dequantize_padded, quantize_padded

_LANE = 128


def _to_tiles(flat: jax.Array) -> Tuple[jax.Array, int]:
    n = flat.shape[0]
    m = -(-n // _LANE)
    m8 = -(-m // 8) * 8                      # sublane alignment
    padded = jnp.zeros((m8 * _LANE,), flat.dtype).at[:n].set(flat)
    return padded.reshape(m8, _LANE), n


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize(x: jax.Array, *, interpret: bool = True
             ) -> Tuple[jax.Array, jax.Array]:
    """Any-shape fp tensor → (q int8 same shape, scale f32 scalar)."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    tiles, n = _to_tiles(x32.reshape(-1))
    block_m = min(tiles.shape[0], 512)
    # pad rows to a block multiple
    m = tiles.shape[0]
    mpad = -(-m // block_m) * block_m
    if mpad != m:
        tiles = jnp.zeros((mpad, _LANE), tiles.dtype).at[:m].set(tiles)
    q = quantize_padded(tiles, scale.reshape(1, 1), block_m=block_m,
                        interpret=interpret)
    return q.reshape(-1)[:n].reshape(x.shape), scale


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize(q: jax.Array, scale: jax.Array, *,
               interpret: bool = True) -> jax.Array:
    tiles, n = _to_tiles(q.reshape(-1))
    block_m = min(tiles.shape[0], 512)
    m = tiles.shape[0]
    mpad = -(-m // block_m) * block_m
    if mpad != m:
        tiles = jnp.zeros((mpad, _LANE), tiles.dtype).at[:m].set(tiles)
    x = dequantize_padded(tiles.astype(jnp.int8), scale.reshape(1, 1),
                          block_m=block_m, interpret=interpret)
    return x.reshape(-1)[:n].reshape(q.shape)
