"""Oracle for the int8 quant kernels (same math as core.compression)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale
