from repro.kernels.quant import ops, ref

__all__ = ["ops", "ref"]
