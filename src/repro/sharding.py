"""Logical-axis sharding: rules map logical dim names → mesh axes.

Model code annotates arrays with *logical* axis names (``("batch", "seq",
"embed")``); the active :class:`ShardingRules` decides which mesh axis each
logical name lands on, with automatic fallback to replication when a dim
size is not divisible by the mesh axis size (e.g. smollm's 15 heads on a
16-way model axis).

Usage::

    with use_rules(rules_for(mesh_cfg), mesh):
        y = constrain(y, "batch", "seq", "embed")
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import MeshConfig

MeshAxes = Union[None, str, Tuple[str, ...]]

# Default logical→mesh assignment. "fsdp" role rides the data axis; tensor
# parallel rides the model axis; the local-SGD replica dim rides the pod axis.
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "replica": ("pod",),
    "batch": ("data",),
    "seq": (),
    # Megatron-SP: the residual stream / norm activations are sharded along
    # sequence over the model axis (XLA inserts the all-gather before
    # attention/MLP and the reduce-scatter after) — keeps the per-layer scan
    # carry at S/16 per device for the 4k/32k train cells.
    "act_seq": ("model",),
    # context-parallel attention: shard the q-chunk seq dim over model —
    # OFF by default; the §Perf hillclimb enables it for archs whose head
    # counts don't divide the model axis (attention compute/scores would
    # otherwise replicate across it)
    "attn_q_seq": (),
    # grouped-query attention score layout (B, kv, g, s, t): prefer sharding
    # kv heads; when kv doesn't divide the axis (GQA kv=2..8 on a 16-wide
    # model axis) fall through to the q-group dim. spec_for's divisibility +
    # used-axis logic implements the preference order automatically.
    "q_group": ("model",),
    # flattened token dim (B·S): inherits BOTH the batch (data) and act_seq
    # (model) factors — 256-way sharding for MoE dispatch intermediates
    "tokens": ("data", "model"),
    "cache_seq": ("model",),      # sequence-sharded KV cache (flash-decode)
    "embed": ("data",),           # FSDP shard of the contraction dim
    "embed_tp": ("model",),       # 2D-sharded weights for serving
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "expert_embed": ("data",),     # MoE tables' d_model dim (FSDP)
    "expert_cap": ("data",),      # MoE expert-buffer capacity dim
    "expert_mlp": (),
    "layers": (),
    "ssm_state": (),
    "ssm_heads": ("model",),
    "conv": (),
    "stats": (),
}


class ShardingRules:
    def __init__(self, rules: Dict[str, Tuple[str, ...]], mesh: Optional[Mesh]):
        self.rules = dict(rules)
        self.mesh = mesh
        self._axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else {}

    def mesh_axes_for(self, logical: Optional[str]) -> Tuple[str, ...]:
        if logical is None:
            return ()
        axes = self.rules.get(logical, ())
        if axes is None:
            return ()
        if isinstance(axes, str):
            axes = (axes,)
        # drop axes absent from the mesh (e.g. "pod" on the single-pod mesh)
        return tuple(a for a in axes if a in self._axis_sizes)

    def would_shard(self, logical: Optional[str], size: int) -> bool:
        """True if this logical dim of the given size actually shards."""
        axes = self.mesh_axes_for(logical)
        if not axes:
            return False
        total = 1
        for a in axes:
            total *= self._axis_sizes.get(a, 1)
        return total > 1 and size % total == 0

    def spec_for(self, logical_axes: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for one array; replicates non-divisible dims."""
        entries = []
        used: set = set()
        for i, name in enumerate(logical_axes):
            axes = tuple(a for a in self.mesh_axes_for(name) if a not in used)
            if shape is not None and axes:
                size = 1
                for a in axes:
                    size *= self._axis_sizes.get(a, 1)
                if size and shape[i] % size != 0:
                    axes = ()
            used.update(axes)
            if len(axes) == 0:
                entries.append(None)
            elif len(axes) == 1:
                entries.append(axes[0])
            else:
                entries.append(axes)
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def sharding_for(self, logical_axes: Sequence[Optional[str]],
                     shape: Optional[Sequence[int]] = None) -> NamedSharding:
        assert self.mesh is not None
        return NamedSharding(self.mesh, self.spec_for(logical_axes, shape))


_STATE = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_rules(rules: ShardingRules):
    prev = current_rules()
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def rules_for(mesh_cfg: MeshConfig, mesh: Optional[Mesh],
              overrides: Optional[Dict[str, Tuple[str, ...]]] = None) -> ShardingRules:
    rules = dict(DEFAULT_RULES)
    # remap the role axes onto this mesh's axis names
    remap = {"data": mesh_cfg.data_axis, "model": mesh_cfg.model_axis,
             "pod": mesh_cfg.replica_axis or "pod"}
    rules = {k: tuple(remap.get(a, a) for a in (v if not isinstance(v, str) else (v,)))
             if v else ()
             for k, v in rules.items()}
    if overrides:
        rules.update(overrides)
    return ShardingRules(rules, mesh)


def strip_axes(rules: ShardingRules, axes) -> ShardingRules:
    """Rules with the given mesh axes removed from every mapping — used
    inside shard_map bodies where those axes are manual (sharding
    constraints may only reference Auto axes)."""
    axes = set(axes)
    stripped = {k: tuple(a for a in (v if not isinstance(v, str) else (v,))
                         if a not in axes)
                for k, v in rules.rules.items()}
    return ShardingRules(stripped, rules.mesh)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Sharding constraint by logical names; no-op when no rules active."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = rules.spec_for(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, spec)


def tree_specs(logical_tree, shapes_tree, rules: ShardingRules):
    """Map a pytree of logical-axis tuples (+ matching shapes) to PartitionSpecs."""
    return jax.tree.map(
        lambda la, shp: rules.spec_for(la, shp),
        logical_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )
