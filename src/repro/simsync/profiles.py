"""Cluster profiles: the simulator's hardware model.

A :class:`ClusterProfile` is everything the discrete-event engine needs to
replay a sync schedule on a cluster this repo does not have: per-worker
compute-time distributions (persistent slowdowns and transient straggle
events — the two straggler flavors the gossip work decouples differently)
and one link model for the sync fabric (bandwidth + per-hop latency, the
standard α–β collective cost). Wire *bytes* are not modeled here — they
come from :mod:`repro.core.costmodel`, the same accounting the real sync
engine reports, so the simulator and the hardware path can never disagree
about what a sync moves.

Profiles are plain frozen dataclasses (JSON-friendly via ``to_dict``) so a
measured cluster can be captured as a profile file and replayed. The
built-ins in :data:`PROFILES` are calibrated to the repo's two fabrics:

* ``ici_pod``       — intra-pod ICI (50 GB/s, ~µs hops) syncing a small
                      fast model: a distinct comm/compute balance.
* ``dcn_default``   — cross-pod DCN (6.25 GB/s, ~50 µs hops): the paper's
                      regime, oracle H in the tens (Figs 13–15).
* ``dcn_straggler`` — DCN plus one persistently 4× slower worker: the
                      all-reduce barrier inherits the straggler every
                      block; gossip only couples its neighborhood.
* ``dcn_transient`` — DCN with rare 20× transient straggles on every
                      worker (GC pauses / preemption blips).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

# the exact fabrics the auto-tuner models — imported, not redefined, so
# recalibrating one recalibrates both (the whole point of the simulator)
from repro.core.autotune import DCN_BW, ICI_BW

DCN_LATENCY = 50e-6   # seconds per collective hop across the DCN
ICI_LATENCY = 1e-6    # seconds per hop on the intra-pod interconnect


@dataclasses.dataclass(frozen=True)
class LinkProfile:
    """α–β model of the sync fabric: per-hop latency α, bandwidth β."""

    bandwidth: float               # bytes/s per chip
    latency: float = 0.0           # seconds per collective hop
    name: str = "link"


@dataclasses.dataclass(frozen=True)
class WorkerProfile:
    """Per-worker compute-time distribution for one optimizer step.

    A block of H steps costs ``H · step_time · slowdown`` scaled by a
    unit-mean lognormal jitter factor (σ = ``jitter``), times
    ``straggle_factor`` with probability ``straggle_prob`` per block
    (transient straggles hit whole blocks — GC pause / preemption blip).
    """

    step_time: float               # mean seconds per optimizer step
    jitter: float = 0.0            # lognormal sigma of the per-block factor
    slowdown: float = 1.0          # persistent multiplier (straggler if > 1)
    straggle_prob: float = 0.0     # per-block transient straggle probability
    straggle_factor: float = 1.0   # block-time multiplier when straggling


@dataclasses.dataclass(frozen=True)
class ClusterProfile:
    """One simulated cluster: K workers + the sync-fabric link.

    ``param_bytes`` is the fp32 footprint of the synced tree per chip —
    fed to ``costmodel.wire_bytes_per_sync`` exactly like the real engine's
    byte accounting.
    """

    name: str
    workers: Tuple[WorkerProfile, ...]
    link: LinkProfile
    param_bytes: int

    @property
    def world(self) -> int:
        return len(self.workers)

    def step_times(self) -> np.ndarray:
        return np.array([w.step_time * w.slowdown for w in self.workers])

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "ClusterProfile":
        return ClusterProfile(
            name=d["name"],
            workers=tuple(WorkerProfile(**w) for w in d["workers"]),
            link=LinkProfile(**d["link"]),
            param_bytes=int(d["param_bytes"]))


def uniform_profile(name: str, k: int, *, step_time: float, jitter: float,
                    bandwidth: float, latency: float, param_bytes: int,
                    slow_workers: Dict[int, float] = None,
                    straggle_prob: float = 0.0,
                    straggle_factor: float = 1.0) -> ClusterProfile:
    """K same-spec workers, optionally with per-index persistent slowdowns."""
    slow = slow_workers or {}
    workers = tuple(
        WorkerProfile(step_time=step_time, jitter=jitter,
                      slowdown=slow.get(i, 1.0),
                      straggle_prob=straggle_prob,
                      straggle_factor=straggle_factor)
        for i in range(k))
    return ClusterProfile(name=name, workers=workers,
                          link=LinkProfile(bandwidth=bandwidth,
                                           latency=latency, name=name),
                          param_bytes=param_bytes)


def dcn_profile(k: int = 8, *, step_time: float = 2e-3, jitter: float = 0.02,
                param_bytes: int = 8_000_000, name: str = "dcn_default",
                **kw) -> ClusterProfile:
    """Cross-pod DCN sync: the paper's comm-bound regime (T_sync ≈ T_step,
    oracle H in the tens — the Figs 13–15 ladder)."""
    return uniform_profile(name, k, step_time=step_time, jitter=jitter,
                           bandwidth=DCN_BW, latency=DCN_LATENCY,
                           param_bytes=param_bytes, **kw)


def ici_profile(k: int = 8, *, step_time: float = 5e-4, jitter: float = 0.01,
                param_bytes: int = 8_000_000, name: str = "ici_pod",
                **kw) -> ClusterProfile:
    """Intra-pod ICI sync: 8× the DCN bandwidth and µs hops, paired with a
    small fast model — a *different* comm/compute balance than the DCN
    profile so the controller is graded on two distinct operating points."""
    return uniform_profile(name, k, step_time=step_time, jitter=jitter,
                           bandwidth=ICI_BW, latency=ICI_LATENCY,
                           param_bytes=param_bytes, **kw)


PROFILES: Dict[str, ClusterProfile] = {
    "dcn_default": dcn_profile(),
    "ici_pod": ici_profile(),
    "dcn_straggler": dcn_profile(name="dcn_straggler",
                                 slow_workers={3: 4.0}),
    "dcn_transient": dcn_profile(name="dcn_transient", straggle_prob=0.02,
                                 straggle_factor=20.0),
}


def get_profile(name: str) -> ClusterProfile:
    if name not in PROFILES:
        raise KeyError(
            f"unknown cluster profile {name!r}; known: {sorted(PROFILES)}")
    return PROFILES[name]
