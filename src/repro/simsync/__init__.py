"""repro.simsync — trace-calibrated cluster simulator for the sync schedule.

Three layers (see ISSUE 3 / ROADMAP):

* :mod:`repro.simsync.profiles` — cluster hardware models (per-worker
  compute distributions incl. stragglers, ICI/DCN link α–β).
* :mod:`repro.simsync.engine` — the discrete-event replay of a full sync
  schedule (topology × overlap × compression × H) on a profile, grounded
  in :mod:`repro.core.costmodel` wire bytes; plus the closed-loop driver
  for :class:`repro.core.autotune.AdaptiveController` and the
  schedule-level ``oracle_h`` it is graded against.
* :mod:`repro.simsync.trace` — Chrome-trace export of the timelines.
"""
from repro.simsync.engine import (BlockStats, ClusterSim, SimResult,  # noqa: F401
                                  oracle_h, simulate, simulate_adaptive,
                                  sync_wire_time_s)
from repro.simsync.profiles import (PROFILES, ClusterProfile,  # noqa: F401
                                    LinkProfile, WorkerProfile, dcn_profile,
                                    get_profile, ici_profile,
                                    uniform_profile)
from repro.simsync.trace import chrome_trace, save_chrome_trace  # noqa: F401
