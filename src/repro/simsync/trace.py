"""Chrome-trace export of simulated timelines.

Produces the ``chrome://tracing`` / Perfetto JSON array-of-events format
(``ph="X"`` complete events, µs timestamps): one ``tid`` lane per worker,
compute/sync/stall slices colored by category. Open the file in
``chrome://tracing`` or https://ui.perfetto.dev to *see* the schedule —
the all-reduce barrier inheriting a straggler vs gossip's one-hop-per-round
propagation is immediately visible, which no CSV row shows.
"""
from __future__ import annotations

import json
from typing import Iterable, List

from repro.simsync.engine import Slice, SimResult

_CATEGORY = {"compute": "compute", "sync": "comm", "stall": "stall"}
# chrome://tracing's fixed color-name palette
_COLOR = {"compute": "thread_state_running",
          "sync": "rail_response",
          "stall": "terrible"}


def chrome_trace_events(timeline: Iterable[Slice], *, pid: int = 0,
                        label: str = "simsync") -> List[dict]:
    timeline = list(timeline)      # iterated twice; accept generators
    events: List[dict] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": label},
    }]
    workers = sorted({s.worker for s in timeline})
    for w in workers:
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": w, "args": {"name": f"worker {w}"}})
    for s in timeline:
        events.append({
            "name": f"{s.kind} b{s.block}",
            "cat": _CATEGORY.get(s.kind, s.kind),
            "ph": "X",
            "ts": s.start * 1e6,          # chrome traces are in µs
            "dur": max(0.0, (s.end - s.start) * 1e6),
            "pid": pid,
            "tid": s.worker,
            "cname": _COLOR.get(s.kind, ""),
            "args": {"block": s.block},
        })
    return events


def chrome_trace(result: SimResult) -> dict:
    """Full trace document for one simulation run."""
    return {
        "traceEvents": chrome_trace_events(
            result.timeline, label=f"{result.profile} {result.sync_label}"),
        "displayTimeUnit": "ms",
        "otherData": result.summary(),
    }


def save_chrome_trace(path: str, result: SimResult) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(result), f)
    return path
