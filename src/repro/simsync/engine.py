"""Discrete-event simulator for the model-synchronization schedule.

The 2-core CPU host serializes collectives, so the repo's benchmarks
measure barrier latency instead of the paper's headline effect (98% comm
reduction, 16x–24x — Figs 13–15) or the straggler decoupling gossip buys.
This engine replays the *schedule* analytically — the same DAG-timeline
idea as Shi et al. (arXiv:1805.03812) — over a :class:`ClusterProfile`:

* per-block compute times are sampled from each worker's distribution
  (jitter, persistent slowdowns, transient straggles);
* one sync's wire time is ``costmodel.wire_bytes_per_sync(...) / BW`` plus
  the topology's per-hop latency — the *identical* byte accounting the
  hardware sync engine and the auto-tuner read, so simulator and real path
  cannot drift;
* the event recurrence encodes the schedule semantics of
  :mod:`repro.core.sync`:

  - ``topology="all"`` — a sync is a global barrier: it starts at the max
    arrival over all K workers (one straggler stalls everyone).
  - ``"ring"``/``"pairwise"`` — a worker's sync waits only for its
    neighborhood (two ring neighbors / one rotating partner), so a
    straggler's delay propagates one hop per round instead of instantly.
  - ``overlap="none"``/``"chunked"`` — blocking: the worker resumes when
    its collective completes (chunked has already shrunk the wire bytes by
    the shard count via the cost model).
  - ``overlap="delayed"`` — the boundary-*b* collective runs concurrently
    with block *b+1*; the worker stalls at boundary *b+1* only if the
    in-flight collective outlasts that block's compute.
  - ``gossip_async`` (gossip topologies) — *unsynchronized rounds*: a
    worker's sync event waits only on messages that have **arrived**,
    never on a neighbor's round completion. Each boundary consumes the
    last received neighbor payload (nominally the neighbor's previous
    round — the compiled path's 1-round double buffer) and sends its own;
    a payload that has not landed yet simply stays unconsumed and the
    buffer's staleness grows instead of the worker stalling. A transient
    straggle therefore delays *only the straggled worker's own blocks*;
    its neighbors' clean blocks stay clean (``BlockStats``/``SimResult``
    expose the clean-block mean and the realized buffer staleness so the
    decoupling is measurable).

Every boundary emits per-worker timeline slices (compute / sync / stall)
for the Chrome-trace export (:mod:`repro.simsync.trace`) and per-block
measured ``T_step``/``T_sync`` — the same numbers the hardware telemetry
reports — which is what lets :class:`repro.core.autotune.AdaptiveController`
close its loop against the simulator (``simulate_adaptive``) and be graded
against the schedule-level optimum (``oracle_h``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.config.base import SyncConfig
from repro.core import costmodel
from repro.simsync.profiles import ClusterProfile


@dataclasses.dataclass(frozen=True)
class Slice:
    """One timeline span of one worker (for the Chrome-trace export)."""

    worker: int
    kind: str          # compute | sync | stall
    start: float       # seconds
    end: float
    block: int


@dataclasses.dataclass(frozen=True)
class BlockStats:
    """Per-block measurements — what the real telemetry would report.

    ``compute_max_s`` / ``sync_wire_s`` are the *host-observed* pair: a
    single-controller timed run (``svm.dms_timed_steps``) measures the
    sharded compute until its slowest shard finishes, then the collective
    alone — so arrival spread lands in the compute number and the sync
    number is the barrier-free occupancy. That pair is what calibrates the
    adaptive controller; ``sync_s`` (mean instrumented around the
    collective, straggler waits included — the paper's Figs 10–12
    methodology) is what the comm-breakdown rows report.
    """

    block_s: float        # mean worker wall time of the block
    compute_s: float      # mean worker compute time inside the block
    compute_max_s: float  # slowest worker's compute (host-observed)
    sync_s: float         # mean instrumented collective time (incl. waits)
    sync_wire_s: float    # barrier-free collective occupancy (α·hops + B/β)
    exposed_s: float      # mean critical-path comm exposure


@dataclasses.dataclass
class SimResult:
    profile: str
    sync_label: str
    h: int
    workers: int
    steps: int
    blocks: int
    wall_clock_s: float        # slowest worker's final clock
    compute_s: float           # mean per-worker total compute
    comm_exposed_s: float      # mean per-worker exposed (critical-path) comm
    comm_wire_s: float         # mean per-worker collective occupancy
    timeline: List[Slice]
    # decoupling metrics: a (worker, block) sample is *clean* when that
    # worker did not draw a transient straggle that block. Synchronized
    # schedules leak neighbor straggles into clean blocks (barrier/group
    # waits); async gossip must keep clean blocks at the straggler-free
    # block time — exactly what the acceptance row compares.
    clean_block_mean_s: float = 0.0
    straggled_frac: float = 0.0
    # realized receive-buffer staleness (rounds behind the consumer's
    # round) — async mode only; the nominal double-buffer value is 1
    stale_rounds_mean: float = 0.0
    stale_rounds_max: int = 0

    @property
    def per_step_s(self) -> float:
        return self.wall_clock_s / max(1, self.steps)

    @property
    def comm_fraction(self) -> float:
        tot = self.compute_s + self.comm_exposed_s
        return self.comm_exposed_s / tot if tot > 0 else 0.0

    def summary(self) -> dict:
        return {
            "profile": self.profile, "sync": self.sync_label, "H": self.h,
            "workers": self.workers, "steps": self.steps,
            "blocks": self.blocks, "wall_s": self.wall_clock_s,
            "compute_s": self.compute_s,
            "comm_exposed_s": self.comm_exposed_s,
            "comm_wire_s": self.comm_wire_s,
            "per_step_us": self.per_step_s * 1e6,
            "comm_fraction": self.comm_fraction,
            "clean_block_mean_s": self.clean_block_mean_s,
            "straggled_frac": self.straggled_frac,
            "stale_rounds_mean": self.stale_rounds_mean,
            "stale_rounds_max": self.stale_rounds_max,
        }


def _latency_hops(cfg: SyncConfig, k: int) -> int:
    """Collective hop count for the α (latency) term of one sync."""
    if cfg.topology == "ring":
        return 2                      # two neighbor exchanges
    if cfg.topology == "pairwise":
        return 1                      # one rotating partner
    if cfg.compression == "int8":
        return max(1, k - 1)          # all-gather
    return max(1, 2 * (k - 1))        # ring all-reduce (RS + AG)


def sync_wire_time_s(profile: ClusterProfile, cfg: SyncConfig) -> float:
    """Occupancy of ONE executed collective: α·hops + bytes/β.

    Bytes come from the shared cost model (including compression and the
    chunked ``/chunks`` factor) — one formula, three consumers (hardware
    engine, auto-tuner, simulator).
    """
    k = max(2, profile.world)
    wire = costmodel.wire_bytes_per_sync(profile.param_bytes, k, cfg)
    return (profile.link.latency * _latency_hops(cfg, k)
            + wire / profile.link.bandwidth)


class ClusterSim:
    """Incremental discrete-event simulation: one ``run_block(h)`` per sync
    block, so a controller can sit in the loop and change H between blocks.
    """

    def __init__(self, profile: ClusterProfile, cfg: Optional[SyncConfig] = None,
                 *, seed: int = 0, record_timeline: bool = False):
        self.profile = profile
        self.cfg = cfg or SyncConfig(strategy="periodic")
        if self.cfg.topology == "pairwise" and profile.world % 2:
            raise ValueError("topology='pairwise' needs an even worker count")
        self.async_rounds = bool(self.cfg.gossip_async)
        if self.async_rounds and self.cfg.topology == "all":
            raise ValueError("gossip_async needs a gossip topology "
                             "(ring/pairwise)")
        k = profile.world
        self.k = k
        self.rng = np.random.default_rng(seed)
        self.t = np.zeros(k)                    # per-worker clock
        self._inflight: Optional[np.ndarray] = None   # delayed-collective done
        self.block_idx = 0
        self.steps = 0
        self.record_timeline = record_timeline
        self.timeline: List[Slice] = []
        self.compute_total = np.zeros(k)
        self.exposed_total = np.zeros(k)
        self.wire_total = np.zeros(k)
        # decoupling accounting: block durations split by whether the
        # worker itself drew a transient straggle that block
        self._clean_dur = 0.0
        self._clean_n = 0
        self._hit_n = 0
        self._last_hit = np.zeros(k, bool)
        # async: per-block send-launch history (for message-arrival lookups)
        # + realized receive staleness stats. Sender index arrays depend
        # only on round parity (ring not even on that) — precompute both.
        self._launch_hist: List[np.ndarray] = []
        if self.async_rounds:
            self._senders = (self._in_senders(0), self._in_senders(1))
        self._stale_sum = 0.0
        self._stale_n = 0
        self._stale_max = 0
        self.t_comm = sync_wire_time_s(profile, self.cfg)
        self._step_mean = np.array([w.step_time * w.slowdown
                                    for w in profile.workers])
        self._jitter = np.array([w.jitter for w in profile.workers])
        self._straggle_p = np.array([w.straggle_prob for w in profile.workers])
        self._straggle_f = np.array([w.straggle_factor
                                     for w in profile.workers])

    # ------------------------------------------------------------------
    def _sample_compute(self, h: int) -> np.ndarray:
        base = h * self._step_mean
        if self._jitter.any():
            # per-STEP noise: independent step jitter averages out over the
            # block (CLT), so the block's relative spread is jitter/sqrt(H).
            # A single per-block factor would make barrier waits grow ∝ H
            # and fabricate a runaway feedback for the adaptive controller.
            sig = self._jitter / np.sqrt(h)
            # unit-mean lognormal so jitter never biases the mean step time
            base = base * self.rng.lognormal(-sig ** 2 / 2, sig)
        if self._straggle_p.any():
            hit = self.rng.random(self.k) < self._straggle_p
            base = np.where(hit, base * self._straggle_f, base)
            self._last_hit = hit
        else:
            self._last_hit = np.zeros(self.k, bool)
        return base

    def _in_senders(self, rnd: int) -> List[np.ndarray]:
        """Per-worker sender indices of the round-``rnd`` exchange (one
        array per incoming wire slot: ring two, pairwise one)."""
        i = np.arange(self.k)
        if self.cfg.topology == "ring":
            return [np.roll(i, 1), np.roll(i, -1)]
        if rnd % 2 == 0:
            return [i ^ 1]
        return [np.where(i % 2 == 0, (i - 1) % self.k, (i + 1) % self.k)]

    def _account_staleness(self, b: int, t_now: np.ndarray) -> None:
        """Record the realized receive-buffer staleness at boundary ``b``:
        for each incoming wire, how many rounds behind the *last arrived*
        message is (nominal double-buffer value: 1). Seed buffers (no
        message arrived yet) are skipped. The backward scan breaks at the
        first (latest) arrived round — normally immediately, and only a
        worker whose sender fell r rounds behind scans r entries."""
        hist = self._launch_hist            # includes this block at [b]
        slots = len(self._senders[0])
        for i in range(self.k):
            deadline = t_now[i]
            for slot in range(slots):
                for r in range(b, -1, -1):
                    j = int(self._senders[r % 2][slot][i])
                    if hist[r][j] + self.t_comm <= deadline:
                        s = b - r
                        self._stale_sum += s
                        self._stale_n += 1
                        if s > self._stale_max:
                            self._stale_max = s
                        break

    def _group_max(self, arr: np.ndarray) -> np.ndarray:
        """Per-worker max arrival over its sync coupling group."""
        if self.k == 1:
            return arr
        topo = self.cfg.topology
        if topo == "all":
            return np.full(self.k, arr.max())
        if topo == "ring":
            return np.maximum(arr, np.maximum(np.roll(arr, 1),
                                              np.roll(arr, -1)))
        # pairwise: alternating odd–even pairings (parity per executed
        # round; chunked advances it once per full round-robin pass —
        # mirrors sync.py's ``chunk_idx // chunks``)
        rnd = self.block_idx
        if self.cfg.overlap == "chunked":
            rnd = self.block_idx // max(1, self.cfg.chunks)
        i = np.arange(self.k)
        if rnd % 2 == 0:
            partner = i ^ 1
        else:
            partner = np.where(i % 2 == 0, (i - 1) % self.k,
                               (i + 1) % self.k)
        return np.maximum(arr, arr[partner])

    # ------------------------------------------------------------------
    def run_block(self, h: int) -> BlockStats:
        """Advance every worker through H local steps + one sync point."""
        h = max(1, int(h))
        start = self.t.copy()
        comp = self._sample_compute(h)
        comp_end = start + comp
        b = self.block_idx

        if self.async_rounds:
            # unsynchronized rounds: the boundary consumes whatever has
            # arrived (never waits on a neighbor's round) and launches its
            # own send, which runs under the next block's compute — zero
            # critical-path exposure; a late message only grows the
            # consumer's buffer staleness (accounted below)
            launch = comp_end
            new_t = comp_end.copy()
            sync_meas = np.zeros(self.k)
            exposed = np.zeros(self.k)
            self._launch_hist.append(launch.copy())
            self._account_staleness(b, new_t)
        elif self.cfg.overlap == "delayed":
            # stall only if the previous boundary's collective outlasts
            # this block's compute
            boundary = (np.maximum(comp_end, self._inflight)
                        if self._inflight is not None else comp_end)
            stall = boundary - comp_end
            launch = boundary
            done = self._group_max(boundary) + self.t_comm
            sync_meas = done - launch        # instrumenting the collective
            self._inflight = done
            new_t = boundary
            exposed = stall
        else:
            # blocking (none/chunked): barrier wait + wire on the critical path
            launch = comp_end
            sync_start = self._group_max(comp_end)
            done = sync_start + self.t_comm
            sync_meas = done - launch
            new_t = done
            exposed = done - comp_end

        if self.record_timeline:
            for i in range(self.k):
                self.timeline.append(Slice(i, "compute", start[i],
                                           comp_end[i], b))
                if self.async_rounds:
                    # the non-blocking send: occupies the wire under the
                    # next block's compute, no stall lane ever
                    self.timeline.append(Slice(i, "sync", launch[i],
                                               launch[i] + self.t_comm, b))
                elif self.cfg.overlap == "delayed":
                    if exposed[i] > 0:
                        self.timeline.append(Slice(i, "stall", comp_end[i],
                                                   new_t[i], b))
                    self.timeline.append(Slice(i, "sync", launch[i], done[i],
                                               b))
                else:
                    self.timeline.append(Slice(i, "sync", comp_end[i],
                                               done[i], b))

        dur = new_t - start
        clean = ~self._last_hit
        self._clean_dur += float(dur[clean].sum())
        self._clean_n += int(clean.sum())
        self._hit_n += int(self._last_hit.sum())
        self.t = new_t
        self.block_idx += 1
        self.steps += h
        self.compute_total += comp
        self.exposed_total += exposed
        self.wire_total += self.t_comm
        return BlockStats(block_s=float(np.mean(new_t - start)),
                          compute_s=float(np.mean(comp)),
                          compute_max_s=float(np.max(comp)),
                          sync_s=float(np.mean(sync_meas)),
                          sync_wire_s=self.t_comm,
                          exposed_s=float(np.mean(exposed)))

    def drain(self) -> None:
        """Wait out the last in-flight delayed collective (end of training)."""
        if self._inflight is not None:
            stall = np.maximum(self._inflight - self.t, 0.0)
            self.exposed_total += stall
            if self.record_timeline:
                for i in range(self.k):
                    if stall[i] > 0:
                        self.timeline.append(Slice(i, "stall", self.t[i],
                                                   self._inflight[i],
                                                   self.block_idx))
            self.t = np.maximum(self.t, self._inflight)
            self._inflight = None

    def result(self, h_label: int) -> SimResult:
        self.drain()
        samples = self.k * max(1, self.block_idx)
        return SimResult(
            profile=self.profile.name, sync_label=self.cfg.msf_label,
            h=h_label, workers=self.k, steps=self.steps,
            blocks=self.block_idx, wall_clock_s=float(self.t.max()),
            compute_s=float(self.compute_total.mean()),
            comm_exposed_s=float(self.exposed_total.mean()),
            comm_wire_s=float(self.wire_total.mean()),
            timeline=self.timeline,
            clean_block_mean_s=(self._clean_dur / self._clean_n
                                if self._clean_n else 0.0),
            straggled_frac=self._hit_n / samples,
            stale_rounds_mean=(self._stale_sum / self._stale_n
                               if self._stale_n else 0.0),
            stale_rounds_max=self._stale_max)


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def simulate(profile: ClusterProfile, cfg: Optional[SyncConfig] = None, *,
             h: int, steps: int = 0, blocks: int = 0, seed: int = 0,
             record_timeline: bool = False) -> SimResult:
    """Replay a fixed-H schedule. Give ``steps`` (total optimizer steps —
    the fixed-work comparison the comm ∝ 1/H curve needs) or ``blocks``."""
    if not blocks:
        if not steps:
            raise ValueError("pass steps= or blocks=")
        blocks = max(1, steps // max(1, h))
    sim = ClusterSim(profile, cfg, seed=seed,
                     record_timeline=record_timeline)
    for _ in range(blocks):
        sim.run_block(h)
    return sim.result(h)


def simulate_adaptive(profile: ClusterProfile, cfg: SyncConfig, controller, *,
                      blocks: int, seed: int = 0,
                      record_timeline: bool = False
                      ) -> Tuple[SimResult, List[Tuple[int, int]]]:
    """Closed loop: the controller picks each block's H from the simulated
    telemetry (measured per-step compute + instrumented collective time) —
    the simulator standing in for the cluster the controller would tune on.
    Returns the result plus the controller's ``(block, H)`` history.
    """
    sim = ClusterSim(profile, cfg, seed=seed,
                     record_timeline=record_timeline)
    for _ in range(blocks):
        h = controller.h
        stats = sim.run_block(h)
        # feed the host-observed pair (see BlockStats): slowest-shard
        # compute + barrier-free collective — mean instrumented sync would
        # fold straggler wait into T_sync and make the re-solve chase its
        # own barrier (H runaway)
        controller.observe_block(step_s=stats.compute_max_s / max(1, h),
                                 sync_s=stats.sync_wire_s)
    return sim.result(controller.h), list(controller.history)


def oracle_h(profile: ClusterProfile, cfg: Optional[SyncConfig] = None, *,
             target_overhead: float = 0.05, steps: int = 4096,
             h_max: int = 1024, seed: int = 0) -> int:
    """The simulator's ground-truth H: the smallest period whose simulated
    per-step time is within ``1 + target_overhead`` of the compute-bound
    floor (per-step time at ``h_max``) — the same "as low an MSF as helps,
    and no lower" objective ``choose_period`` solves analytically, but
    graded on the replayed schedule (barrier waits, stragglers, overlap
    exposure included). Bisection is valid because per-step time is
    monotone non-increasing in H.
    """
    def per_step(h: int) -> float:
        return simulate(profile, cfg, h=h, steps=steps, seed=seed).per_step_s

    floor = per_step(h_max)
    budget = (1.0 + target_overhead) * floor
    if per_step(1) <= budget:
        return 1
    lo, hi = 1, h_max                 # per_step(lo) > budget ≥ per_step(hi)
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if per_step(mid) <= budget:
            hi = mid
        else:
            lo = mid
    return hi
