"""repro — parallel-SGD SVM / MSF training framework (paper reproduction).

Importing the package installs :mod:`repro.compat`, which backfills
new-style JAX API names on older jaxlib installs.
"""
from repro import compat as _compat  # noqa: F401
