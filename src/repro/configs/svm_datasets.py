"""The paper's three SVM workloads as configs (synthetic stand-ins).

Geometry (n, d, sparsity) follows Table I; see
:mod:`repro.data.synthetic` for the stand-in generation rationale.
"""
from repro.config.base import DataConfig

IJCNN1 = DataConfig(dataset="ijcnn1", features=22, num_samples=35_000,
                    sparsity=40.91)
WEBSPAM = DataConfig(dataset="webspam", features=254, num_samples=350_000,
                     sparsity=99.9)
EPSILON = DataConfig(dataset="epsilon", features=2_000, num_samples=400_000,
                     sparsity=44.9)

SVM_DATASETS = {"ijcnn1": IJCNN1, "webspam": WEBSPAM, "epsilon": EPSILON}
