"""Assigned architecture configs. Importing this package registers all ten
``--arch`` ids (plus the paper's SVM dataset configs in ``svm_datasets``)."""

from repro.configs import (  # noqa: F401
    internlm2_1p8b,
    llama32_3b,
    mamba2_2p7b,
    paligemma_3b,
    phi35_moe,
    qwen25_3b,
    qwen3_moe,
    smollm_360m,
    whisper_base,
    zamba2_1p2b,
)
from repro.configs import svm_datasets  # noqa: F401
from repro.configs.sync_presets import (  # noqa: F401
    SYNC_PRESETS,
    get_sync_preset,
)
