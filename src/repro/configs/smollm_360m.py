"""smollm-360m — llama-arch small model with non-power-of-two heads.

[hf:HuggingFaceTB/SmolLM-135M (family); hf]
32L · d_model 960 · 15H (kv 5, head_dim 64) · d_ff 2560 · vocab 49152.

15 query heads / 5 kv heads do NOT divide the 16-way model axis: the
sharding rules detect this and fall back to replicating the head dims
(see DESIGN.md §Head-count alignment) — at 360M this costs nothing.
"""
from repro.config.base import ModelConfig
from repro.config.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m",
        family="dense",
        n_layers=32,
        d_model=960,
        n_heads=15,
        n_kv_heads=5,
        d_ff=2560,
        vocab_size=49152,
        tie_embeddings=True,
        ce_chunk=512,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="smollm-smoke",
        family="dense",
        n_layers=2,
        d_model=96,
        n_heads=3,          # keeps the non-divisible head count property
        n_kv_heads=1,
        d_ff=256,
        vocab_size=512,
        tie_embeddings=True,
    )


register_arch("smollm-360m", full, smoke)
