"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE, GQA kv=8.

[hf:microsoft/Phi-3.5-MoE-instruct; hf]
32L · d_model 4096 · 32H (kv 8, head_dim 128) · d_ff 6400/expert ·
vocab 32064 · 16e top-2 ⇒ 41.9B total / 6.6B active.
"""
from repro.config.base import ModelConfig, MoEConfig
from repro.config.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6400,
        vocab_size=32064,
        moe=MoEConfig(num_experts=16, top_k=2),
        ce_chunk=512,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=192,
        vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2),
    )


register_arch("phi3.5-moe-42b-a6.6b", full, smoke)
