"""qwen2.5-3b — GQA kv=2, QKV bias.

[hf:Qwen/Qwen2.5-0.5B (family); hf]
36L · d_model 2048 · 16H (kv 2, head_dim 128) · d_ff 11008 · vocab 151936.
"""
from repro.config.base import ModelConfig
from repro.config.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b",
        family="dense",
        n_layers=36,
        d_model=2048,
        n_heads=16,
        n_kv_heads=2,
        d_ff=11008,
        vocab_size=151936,
        qkv_bias=True,
        ce_chunk=512,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=320,
        vocab_size=512,
        qkv_bias=True,
    )


register_arch("qwen2.5-3b", full, smoke)
