"""mamba2-2.7b — attention-free SSM (state-space duality).

[arXiv:2405.21060; unverified]
64L · d_model 2560 (d_inner 5120, 80 SSD heads × head_dim 64) ·
ssm_state 128 · vocab 50280. Sub-quadratic ⇒ runs the long_500k cell.
"""
from repro.config.base import ModelConfig, SSMConfig
from repro.config.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        family="ssm",
        n_layers=64,
        d_model=2560,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=256),
        subquadratic=True,
        ce_chunk=512,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=512,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, chunk_size=8),
        subquadratic=True,
    )


register_arch("mamba2-2.7b", full, smoke)
