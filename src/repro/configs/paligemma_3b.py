"""paligemma-3b — SigLIP (stub) + gemma-2b prefix-LM decoder.

[arXiv:2407.07726; hf]
18L · d_model 2048 · 8H (kv 1 = MQA, head_dim 256) · d_ff 16384 ·
vocab 257216 · 256 image-prefix tokens (224px / 14px patches).

The SigLIP tower is a STUB per the brief: ``input_layout`` takes
precomputed patch embeddings (B, 256, 2048). ``seq`` in each shape cell is
the TOTAL (image + text) length; the loss covers text positions only,
prefix attention is bidirectional over the image tokens.
"""
from repro.config.base import ModelConfig
from repro.config.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b",
        family="vlm",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        d_ff=16384,
        vocab_size=257216,
        head_dim=256,
        tie_embeddings=True,
        num_image_tokens=256,
        ce_chunk=480,      # divides the 3840/32512-token text spans
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="paligemma-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=512,
        head_dim=32,
        tie_embeddings=True,
        num_image_tokens=8,
    )


register_arch("paligemma-3b", full, smoke)
