"""whisper-base — encoder-decoder audio backbone, conv frontend stubbed.

[arXiv:2212.04356; unverified]
6L enc + 6L dec · d_model 512 · 8H (kv 8, head_dim 64) · d_ff 2048 ·
vocab 51865 · LayerNorm · tied embeddings · 1500 audio frames (30 s).

The conv1d/mel frontend is a STUB per the brief: ``input_layout`` expects
precomputed frame embeddings (B, 1500, 512). Shape cells apply the
decoder-side seq_len (noted in DESIGN.md: real whisper has a 448-token
decoder context; the 4k/32k cells stress the backbone as mandated).
"""
from repro.config.base import ModelConfig
from repro.config.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="audio",
        n_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab_size=51865,
        norm_type="layer",
        tie_embeddings=True,
        n_encoder_layers=6,
        n_audio_frames=1500,
        ce_chunk=512,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        norm_type="layer",
        tie_embeddings=True,
        n_encoder_layers=2,
        n_audio_frames=16,
    )


register_arch("whisper-base", full, smoke)
