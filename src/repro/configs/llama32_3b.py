"""llama3.2-3b — small llama3, GQA kv=8.

[hf:meta-llama/Llama-3.2-1B (family); unverified]
28L · d_model 3072 · 24H (kv 8, head_dim 128) · d_ff 8192 · vocab 128256.
"""
from repro.config.base import ModelConfig
from repro.config.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=128256,
        rope_theta=500000.0,
        ce_chunk=512,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        rope_theta=500000.0,
    )


register_arch("llama3.2-3b", full, smoke)
