"""Named model-synchronization schedules (the sync analog of ``--arch``).

One place that pins the combinations the experiments sweep, so launch
scripts and benchmarks reference a preset id instead of re-assembling
``SyncConfig`` fields. ``--set sync.topology=ring``-style dotted overrides
still compose on top.

The gossip presets pair a sparse topology with ``overlap="delayed"`` by
default: gossip already removed the global barrier, delayed overlap
additionally takes the two ppermutes off the block's critical path — the
full straggler-decoupled schedule the ROADMAP's gossip item asks for.
"""
from __future__ import annotations

from typing import Dict

from repro.config.base import SyncConfig

SYNC_PRESETS: Dict[str, SyncConfig] = {
    # the paper's DMS: blocking global average every H steps
    "paper_blocking": SyncConfig(strategy="periodic", period=64),
    # PR 1's overlap engine on the global collective
    "overlap_delayed": SyncConfig(strategy="periodic", period=64,
                                  overlap="delayed"),
    # gossip: no global barrier at all (ISSUE 2 tentpole)
    "gossip_ring": SyncConfig(strategy="periodic", period=64,
                              topology="ring", overlap="delayed"),
    "gossip_pairwise": SyncConfig(strategy="periodic", period=64,
                                  topology="pairwise", overlap="delayed"),
    # gossip + compressed point-to-point wire (int16 needs no psum headroom
    # on the neighbor exchange — full range per sender)
    "gossip_ring_int16": SyncConfig(strategy="periodic", period=64,
                                    topology="ring", overlap="delayed",
                                    compression="int16"),
    # asynchronous (unsynchronized-round) gossip (ISSUE 4): double-buffered
    # ppermute exchange — each replica mixes with the last *received*
    # neighbor snapshot (bounded staleness = 1 round), so a transient
    # straggler delays only itself. overlap stays "none": the exchange is
    # already a full block off the critical path by construction.
    "gossip_ring_async": SyncConfig(strategy="periodic", period=64,
                                    topology="ring", gossip_async=True),
    "gossip_pairwise_async": SyncConfig(strategy="periodic", period=64,
                                        topology="pairwise",
                                        gossip_async=True),
    # hierarchical flavor: every-step data-axis sync, gossip across pods
    "hierarchical_gossip_ring": SyncConfig(strategy="hierarchical",
                                           period=64, topology="ring",
                                           overlap="delayed"),
    # adaptive MSF (ISSUE 3): the controller re-solves H online from
    # measured T_step/T_sync every adapt_every blocks — `period` is only
    # the starting point. DCN flavor starts low and grows into the fabric;
    # the gossip flavor keeps the spectral-gap cap in the loop.
    "adaptive_dcn": SyncConfig(strategy="hierarchical", period=8,
                               overlap="delayed", adaptive=True,
                               adapt_every=16),
    "adaptive_gossip_ring": SyncConfig(strategy="periodic", period=8,
                                       topology="ring", overlap="delayed",
                                       adaptive=True, adapt_every=16),
    # mid-run adaptive MSF via the pre-compiled H-ladder (ISSUE 5): the
    # trainer AOT-compiles every rung of the geometric ladder
    # {1,2,…,adapt_h_max} at launch and the controller moves between them
    # live — an H change is a flush + switch, zero recompiles. Rung
    # hysteresis replaces the relative-band knob (geometric spacing
    # already absorbs sub-2x noise).
    "adaptive_ladder_dcn": SyncConfig(strategy="hierarchical", period=8,
                                      overlap="delayed", adaptive=True,
                                      adapt_every=8, adapt_h_max=64),
    "adaptive_ladder_gossip_ring": SyncConfig(strategy="periodic", period=8,
                                              topology="ring",
                                              overlap="delayed",
                                              adaptive=True, adapt_every=8,
                                              adapt_h_max=64),
}


def get_sync_preset(name: str) -> SyncConfig:
    if name not in SYNC_PRESETS:
        raise KeyError(
            f"unknown sync preset {name!r}; known: {sorted(SYNC_PRESETS)}")
    return SYNC_PRESETS[name]
