"""internlm2-1.8b — GQA dense LM.

[arXiv:2403.17297; hf]
24L · d_model 2048 · 16H (kv 8, head_dim 128) · d_ff 8192 · vocab 92544.
"""
from repro.config.base import ModelConfig
from repro.config.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=92544,
        ce_chunk=512,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internlm2-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=512,
    )


register_arch("internlm2-1.8b", full, smoke)
