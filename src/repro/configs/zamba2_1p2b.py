"""zamba2-1.2b — Mamba2 backbone + shared attention block (hybrid).

[arXiv:2411.15242; hf]
38L Mamba2 (d_inner 4096, 64 SSD heads × 64) · shared attn+MLP block with
32H (kv 32, head_dim 64) + d_ff 8192, applied every 6 backbone layers ·
ssm_state 64 · vocab 32000. Sub-quadratic ⇒ runs long_500k (its 6 shared
attention caches shard along cache_seq with distributed flash-decode).

Deviations from published zamba2 noted in DESIGN.md §5: shared-block input
concatenation and LoRA adapters omitted.
"""
from repro.config.base import ModelConfig, SSMConfig
from repro.config.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk_size=256),
        shared_block_every=6,
        subquadratic=True,
        ce_chunk=512,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        family="hybrid",
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, chunk_size=8),
        shared_block_every=2,
        subquadratic=True,
    )


register_arch("zamba2-1.2b", full, smoke)
