"""qwen3-moe-235b-a22b — 128-expert top-8 MoE, the largest assigned arch.

[hf:Qwen/Qwen3-30B-A3B (family); hf]
94L · d_model 4096 · 64H (kv 4, head_dim 128 explicit) · d_ff 1536/expert ·
vocab 151936 · 128e top-8 ⇒ ~235B total / ~22B active. Needs FSDP×TP×EP.
"""
from repro.config.base import ModelConfig, MoEConfig
from repro.config.registry import register_arch


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        d_ff=1536,
        vocab_size=151936,
        head_dim=128,
        moe=MoEConfig(num_experts=128, top_k=8),
        ce_chunk=512,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke",
        family="moe",
        n_layers=3,
        d_model=128,
        n_heads=8,
        n_kv_heads=2,
        d_ff=64,
        vocab_size=512,
        head_dim=32,
        moe=MoEConfig(num_experts=8, top_k=2),
    )


register_arch("qwen3-moe-235b-a22b", full, smoke)
