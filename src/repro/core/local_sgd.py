"""Local-SGD (MSF) trainer: the paper's DMS algorithm generalized to LMs.

Two compiled step flavors, selected by ``SyncConfig.strategy``:

* ``sync_every_step`` → :func:`make_ddp_step`. Canonical data-parallel
  training: batch sharded over the data (and pod) axes, XLA inserts the
  gradient all-reduce every step. This is the paper's MSF=1 analog and the
  paper-faithful baseline the roofline table records first.

* ``periodic`` / ``hierarchical`` → :func:`make_local_sgd_block`. The
  paper's DMS: replicas (mesh axis ``replica_axis``, the ``pod``/DCN axis on
  the production mesh) each take H optimizer steps on their own batch
  shard, then average parameters (``sync_point``). One compiled
  ``train_block`` = ``lax.scan`` over H microbatches + one sync, expressed
  as a *partial-manual* ``jax.shard_map``: the replica axis is manual
  (params carry a leading replica dim, divergent between syncs), while the
  data/model axes stay in XLA auto mode so the inner step still gets
  FSDP + tensor parallelism from sharding constraints. The compiled HLO is
  therefore the full collective schedule — ICI collectives every microbatch,
  one DCN sync per block — which is exactly what the roofline reads.

  ``SyncConfig.overlap`` flows through ``sync_point`` unchanged here:
  ``"delayed"`` makes the block's DCN collective feed only the carried
  ``sync`` state (the stale correction applied next block), so XLA can
  schedule it under the next block's compute; ``"chunked"`` syncs one
  round-robin parameter shard per block. Note that under either mode the
  replicas are *not* byte-identical right after a block — they converge to
  anchor + own last block's drift (delayed) or per-leaf staleness ≤
  ``chunks`` blocks (chunked); see :mod:`repro.core.sync`.
  ``SyncConfig.topology`` ∈ {ring, pairwise} swaps the block's global
  collective for ``ppermute`` neighbor mixing (gossip) — no global barrier,
  replicas stay within the geometric consensus envelope and
  :func:`finalize_state` collapses them via the (invariant) replica mean.

State layout (plain dict → trivially checkpointable):

    {"params": …, "opt": …, "sync": …, "step": i32[]}

Under local SGD every leaf of params/opt/sync gains a leading ``replica``
dim. Optimizer moments stay *local* to each replica between syncs (standard
local-SGD practice; averaging them is a config flag away but costs another
collective).
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import TrainConfig
from repro.core import sync as S
from repro.models import layers as L
from repro.optim import apply_updates, init_opt_state, opt_state_axes
from repro.sharding import ShardingRules, rules_for, use_rules


# ---------------------------------------------------------------------------
# state construction
# ---------------------------------------------------------------------------


from repro import flags as _flags


def _scan(*args, **kw):
    kw.setdefault("unroll", _flags.scan_unroll_arg())
    return jax.lax.scan(*args, **kw)

def build_state_axes(model, cfg: TrainConfig, replicated: bool):
    """Logical-axes pytree for the full TrainState."""
    param_axes = L.axes_of(model.param_defs())
    axes = {
        "params": param_axes,
        "opt": opt_state_axes(cfg.optimizer, param_axes),
        "sync": S.sync_state_axes(cfg.sync, param_axes),
        "step": (),
    }
    if replicated:
        def add_replica(la):
            return ("replica",) + la
        axes = {
            "params": jax.tree.map(add_replica, axes["params"],
                                   is_leaf=lambda x: isinstance(x, tuple)),
            "opt": jax.tree.map(add_replica, axes["opt"],
                                is_leaf=lambda x: isinstance(x, tuple)),
            "sync": jax.tree.map(add_replica, axes["sync"],
                                 is_leaf=lambda x: isinstance(x, tuple)),
            "step": (),
        }
    return axes


def init_state(model, cfg: TrainConfig, key: jax.Array, replicas: int = 0):
    """``replicas > 0`` adds the leading replica dim (local-SGD layout)."""
    params = model.init(key)
    state = {
        "params": params,
        "opt": init_opt_state(cfg.optimizer, params),
        "sync": S.init_sync_state(cfg.sync, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if replicas:
        bcast = lambda x: jnp.broadcast_to(x, (replicas,) + x.shape)
        state = {
            "params": jax.tree.map(bcast, state["params"]),
            "opt": jax.tree.map(bcast, state["opt"]),
            "sync": jax.tree.map(bcast, state["sync"]),
            "step": state["step"],
        }
    return state


def state_shardings(state_axes, rules: ShardingRules, state_shapes=None):
    """NamedSharding pytree from the logical-axes pytree."""
    def leaf(la, shape=None):
        return rules.sharding_for(la, shape)
    if state_shapes is None:
        return jax.tree.map(lambda la: leaf(la), state_axes,
                            is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree.map(leaf, state_axes, state_shapes,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


# ---------------------------------------------------------------------------
# flavor A — every-step sync (paper baseline / canonical DDP)
# ---------------------------------------------------------------------------

def timed_step(step_fn: Callable, h: int, telemetry, *,
               jit_step: bool = True) -> Callable:
    """Wrap a (state, batch) step with the block-time telemetry hook.

    Jitted code cannot time itself, so the timer brackets the host-side
    call (``block_until_ready`` on the params makes the wall time real).
    ``h`` is the optimizer steps one call advances — the telemetry's key
    for separating T_step from T_sync (see core.telemetry). A Python
    timing closure cannot be jitted by the caller, so by default the
    wrapper owns the jit; pass ``jit_step=False`` for a step that is
    already compiled (e.g. the launch driver's sharded/donating jit —
    re-jitting it would drop those options). Telemetry's warmup discards
    the compile-inflated first sample either way.
    """
    step_c = jax.jit(step_fn) if jit_step else step_fn

    def timed(state, batch):
        t0 = time.perf_counter()
        out = step_c(state, batch)
        jax.block_until_ready(out[0]["params"])
        telemetry.record_block(h, time.perf_counter() - t0)
        return out
    return timed


def make_ddp_step(model, cfg: TrainConfig, mesh: Mesh,
                  rules: Optional[ShardingRules] = None,
                  telemetry=None) -> Callable:
    """(state, batch) → (state, metrics); grad all-reduce every step."""
    rules = rules or rules_for(cfg.mesh, mesh)

    def step(state, batch):
        with use_rules(rules):
            def loss_fn(p):
                loss, aux = model.loss(p, batch)
                return loss, aux
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"])
            params, opt = apply_updates(cfg.optimizer, grads, state["opt"],
                                        state["params"], state["step"])
        new_state = {"params": params, "opt": opt, "sync": state["sync"],
                     "step": state["step"] + 1}
        metrics = {"loss": loss, **aux}
        return new_state, metrics

    return timed_step(step, 1, telemetry) if telemetry is not None else step


# ---------------------------------------------------------------------------
# flavor B — periodic sync over the replica axis (paper's DMS / local SGD)
# ---------------------------------------------------------------------------

def make_local_sgd_block(model, cfg: TrainConfig, mesh: Mesh,
                         rules: Optional[ShardingRules] = None,
                         telemetry=None) -> Callable:
    """(state, batch) → (state, metrics).

    ``batch`` leaves are (H, B_global, …): H microbatches per sync block.
    The replica axis is manual; each replica consumes its batch shard.
    ``telemetry`` (a :class:`repro.core.telemetry.BlockTelemetry`) records
    each block's wall time keyed by H — the measured T_step/T_sync feed
    the simulator's calibration and the adaptive MSF controller.
    """
    replica_axis = cfg.mesh.replica_axis or "pod"
    rules = rules or rules_for(cfg.mesh, mesh)
    # inside the block the replica axis is manual: constraints may only
    # reference the remaining (auto) axes
    from repro.sharding import strip_axes
    inner_rules = strip_axes(rules, {replica_axis})
    unstack = lambda tree: jax.tree.map(lambda x: x[0], tree)
    restack = lambda tree: jax.tree.map(lambda x: x[None], tree)

    def block_body(params, opt, sync_state, step, batch):
        # local (per-replica) views; leading replica dim already stripped to 1
        params = unstack(params)
        opt = unstack(opt)
        sync_state = unstack(sync_state)
        params_start = params

        with use_rules(inner_rules):
            def micro(carry, mb):
                p, o, s = carry
                def loss_fn(pp):
                    return model.loss(pp, mb)
                (loss, aux), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(p)
                p, o = apply_updates(cfg.optimizer, grads, o, p, s)
                return (p, o, s + 1), loss

            (params, opt, step), losses = _scan(
                micro, (params, opt, step), batch)

            params, sync_state = S.sync_point(
                params_start, params, sync_state, cfg.sync, replica_axis,
                param_axes=L.axes_of(model.param_defs()))

            metrics = {"loss": jax.lax.pmean(jnp.mean(losses), replica_axis)}
            if cfg.sync.eval_at_sync:
                # the paper's per-sync convergence check (§V-C2): an extra
                # forward pass on the last microbatch with the *synced*
                # params. Under overlap the block-end params are still
                # per-replica divergent, so reconstruct the synchronized
                # model first: delayed has it as params+pending (identical
                # on every replica under topology="all"); chunked and any
                # gossip topology need a replica mean (gossip consensus is
                # only geometric, but its replica mean is the invariant
                # target of the doubly stochastic mixing).
                eval_params = params
                if cfg.sync.overlap == "delayed":
                    eval_params = jax.tree.map(
                        lambda p, q: (p.astype(jnp.float32) + q
                                      ).astype(p.dtype),
                        params, sync_state["pending"])
                if (cfg.sync.overlap == "chunked"
                        or cfg.sync.topology != "all"):
                    eval_params = jax.tree.map(
                        lambda p: jax.lax.pmean(
                            p.astype(jnp.float32), replica_axis
                        ).astype(p.dtype), eval_params)
                last_mb = jax.tree.map(lambda x: x[-1], batch)
                eval_loss, _ = model.loss(eval_params, last_mb)
                metrics["sync_eval_loss"] = jax.lax.pmean(
                    eval_loss, replica_axis)

        return restack(params), restack(opt), restack(sync_state), step, metrics

    shmapped = jax.shard_map(
        block_body, mesh=mesh,
        in_specs=(P(replica_axis), P(replica_axis), P(replica_axis), P(),
                  P(None, replica_axis)),
        out_specs=(P(replica_axis), P(replica_axis), P(replica_axis), P(),
                   P()),
        axis_names={replica_axis}, check_vma=False)

    def step_fn(state, batch):
        params, opt, sync_state, step, metrics = shmapped(
            state["params"], state["opt"], state["sync"], state["step"],
            batch)
        return ({"params": params, "opt": opt, "sync": sync_state,
                 "step": step}, metrics)

    if telemetry is not None:
        return timed_step(step_fn, max(1, cfg.sync.period), telemetry)
    return step_fn


def finalize_state(state, cfg: TrainConfig):
    """Make the trained state globally consistent before checkpoint/eval.

    Under ``overlap="delayed"``/``"chunked"`` — and any gossip topology,
    whose replicas only ever reach geometric consensus — the replicas are
    intentionally divergent between blocks; this collapses params to the
    fully synchronized model (``sync.flush_overlap``) and clears the
    pending correction *and* the error-feedback residual (flush folds the
    EF into the params, so leaving it in the state would double-count it
    on resume) so training can also resume cleanly from the flushed state.
    A no-op for ``overlap="none"`` with ``topology="all"``.
    """
    if cfg.sync.overlap == "none" and cfg.sync.topology == "all":
        return state
    new_sync = dict(state["sync"])
    if "pending" in new_sync:
        new_sync["pending"] = jax.tree.map(jnp.zeros_like,
                                           new_sync["pending"])
    if "ef" in new_sync:
        new_sync["ef"] = jax.tree.map(jnp.zeros_like, new_sync["ef"])
    flushed = S.flush_overlap(state["params"], state["sync"], cfg.sync)
    if "sent" in new_sync:
        # re-seed the async double buffers from the flushed model so a
        # resume applies a zero stale correction at its first boundary
        # (all replicas restart identical — the same seed as init)
        new_sync["sent"], new_sync["mixbuf"] = S.init_async_buffers(
            flushed, cfg.sync.topology)
    return {**state, "params": flushed, "sync": new_sync}


def ladder_switch_state(state, cfg: TrainConfig):
    """Exact state for resuming the schedule at a *different* H mid-run —
    the H-ladder runtime's switch transform (jittable, layout-preserving).

    :func:`finalize_state` collapses the replicas to the fully
    synchronized model and zeroes/re-seeds the carried sync buffers
    (pending correction, EF residual, async ``sent``/``mixbuf``); on top
    of that the schedule *counters* restart (``chunk_idx``,
    ``gossip_round`` → 0) and the chunked-slowmo ``anchor`` re-seeds from
    the flushed params — exactly :func:`repro.core.sync.init_sync_state`
    evaluated at the flushed model. The result is therefore bit-identical
    to launching a fresh run at the new H from the flushed model (with
    the optimizer state carried over; the slowmo outer momentum is also
    carried — it is optimizer-like state, not schedule state, so a
    switch does not forget it). The state layout is unchanged, which is
    what lets every ladder rung share one compiled signature.
    """
    sync = state["sync"]
    if (cfg.sync.overlap == "none" and cfg.sync.topology == "all"
            and "ef" in sync):
        # finalize_state no-ops here (blocking global sync keeps replicas
        # identical), but the error-feedback residual is live per-replica
        # state a fresh launch would not have: fold its replica mean into
        # the params — exactly what the next sync's averaging would have
        # spread to everyone — and zero the buffer, as the flush does for
        # every other mode.
        params = jax.tree.map(
            lambda p, e: (p.astype(jnp.float32)
                          + jnp.mean(e, axis=0, keepdims=True)
                          ).astype(p.dtype),
            state["params"], sync["ef"])
        state = {**state, "params": params,
                 "sync": {**sync,
                          "ef": jax.tree.map(jnp.zeros_like, sync["ef"])}}
    state = finalize_state(state, cfg)
    new_sync = dict(state["sync"])
    if "chunk_idx" in new_sync:
        new_sync["chunk_idx"] = jnp.zeros_like(new_sync["chunk_idx"])
    if "gossip_round" in new_sync:
        new_sync["gossip_round"] = jnp.zeros_like(new_sync["gossip_round"])
    if "anchor" in new_sync:
        new_sync["anchor"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), state["params"])
    return {**state, "sync": new_sync}


def make_train_step(model, cfg: TrainConfig, mesh: Mesh,
                    rules: Optional[ShardingRules] = None,
                    telemetry=None) -> Callable:
    if S.needs_replica_axis(cfg.sync):
        return make_local_sgd_block(model, cfg, mesh, rules, telemetry)
    return make_ddp_step(model, cfg, mesh, rules, telemetry)
