"""Paper-faithful SGD-SVM: Algorithms 1 (SGD), 2 (SRDMS), 3 (DMS).

Math (paper §III): hinge objective ``J = ½‖w‖² + C·Σ max(0, 1 − y⟨w,x⟩)``,
per-sample subgradient ``∇J = w`` when the margin is met, ``w − C·y·x``
otherwise, update ``w ← w − α∇J`` with ``α = 1/(1+t)`` decaying per epoch.

Block semantics (§IV-B): within a block every point computes its update from
the *same* incoming ``w`` and the block's outgoing weight is the average of
the per-point updated weights — algebraically

    w' = mean_i(w − α∇Jᵢ(w)) = w − α·mean_i(∇Jᵢ(w)),

i.e. the paper's model-synchronizing SGD is mini-batch subgradient descent
with an effective batch of ``K·s_b``. That identity is the paper's own
validation device (DMS ≡ its sequential replica) and is asserted in tests:

    DMS(K workers, block s_b)  ≡  SRDMS(block K·s_b)   (exactly, in fp64)

Three execution backends share the block math:

* :func:`seq_sgd`      — Algorithm 1, ``lax.scan`` over points.
* :func:`srdms`        — Algorithm 2, ``lax.scan`` over blocks.
* :func:`dms`          — Algorithm 3; ``backend="vmap"`` simulates K workers
  on one device (bit-identical math), ``backend="shard_map"`` runs manual
  collectives over the mesh data axis (``MPI_AllReduce`` → ``lax.pmean``).

``grad_impl="pallas"`` routes the block-gradient hot spot through the fused
Pallas kernel (:mod:`repro.kernels.hinge`).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def hinge_objective(w: jax.Array, x: jax.Array, y: jax.Array,
                    c: float = 1.0) -> jax.Array:
    """Paper eq. (2): ½‖w‖² + C·Σ hinge."""
    margins = 1.0 - y * (x @ w)
    return 0.5 * jnp.dot(w, w) + c * jnp.sum(jnp.maximum(0.0, margins))


def accuracy(w: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    pred = jnp.where(x @ w >= 0, 1.0, -1.0)
    return jnp.mean(pred == y)


def block_grad(w: jax.Array, xb: jax.Array, yb: jax.Array, c: float,
               impl: str = "jnp") -> jax.Array:
    """Mean subgradient of a block (same incoming w for every point).

    ``∇ = w − C·mean_i(violᵢ·yᵢ·xᵢ)`` where viol = 1{1 − y⟨w,x⟩ > 0}.
    """
    if impl == "pallas":
        from repro.kernels.hinge import ops as hinge_ops
        return hinge_ops.hinge_block_grad(w, xb, yb, c)
    margins = 1.0 - yb * (xb @ w)
    viol = (margins > 0).astype(w.dtype)
    return w - c * ((viol * yb) @ xb) / xb.shape[0]


def _point_update(w, x, y, alpha, c):
    """Algorithm 1 inner step (single point)."""
    margin = 1.0 - y * jnp.dot(x, w)
    grad = jnp.where(margin > 0, w - c * y * x, w)
    return w - alpha * grad


# ---------------------------------------------------------------------------
# Algorithm 1 — sequential SGD
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("epochs", "c"))
def seq_sgd(w0: jax.Array, x: jax.Array, y: jax.Array, *, epochs: int,
            c: float = 1.0) -> jax.Array:
    def epoch(w, t):
        alpha = 1.0 / (1.0 + t.astype(w.dtype))
        def point(w, xy):
            xi, yi = xy
            return _point_update(w, xi, yi, alpha, c), None
        w, _ = jax.lax.scan(point, w, (x, y))
        return w, None
    w, _ = jax.lax.scan(epoch, w0, jnp.arange(epochs))
    return w


# ---------------------------------------------------------------------------
# Algorithm 2 — SRDMS (sequential replica of the distributed algorithm)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("epochs", "block_size", "c", "grad_impl",
                                    "with_history", "eval_every_sync"))
def srdms(w0: jax.Array, x: jax.Array, y: jax.Array, *, epochs: int,
          block_size: int, c: float = 1.0, grad_impl: str = "jnp",
          x_cv: Optional[jax.Array] = None, y_cv: Optional[jax.Array] = None,
          with_history: bool = False, eval_every_sync: bool = False):
    """Algorithm 2. Data is truncated to a whole number of blocks.

    With ``with_history`` (and cv arrays), returns per-epoch
    (objective, cv_accuracy). ``eval_every_sync=True`` reproduces the
    paper's §V-C2 methodology exactly: the cross-validation accuracy and
    objective are recomputed at EVERY model synchronization (block) — the
    per-sync overhead whose dilution with larger blocks is the paper's
    Figs 2/4 sequential-time effect.
    """
    n, d = x.shape
    nb = n // block_size
    xb = x[: nb * block_size].reshape(nb, block_size, d)
    yb = y[: nb * block_size].reshape(nb, block_size)

    def epoch(w, t):
        alpha = 1.0 / (1.0 + t.astype(w.dtype))
        def block(w, xy):
            xblk, yblk = xy
            w = w - alpha * block_grad(w, xblk, yblk, c, grad_impl)
            if eval_every_sync:
                obj = hinge_objective(w, x, y, c)
                acc = accuracy(w, x_cv, y_cv) if x_cv is not None else jnp.nan
                return w, (obj, acc)
            return w, None
        w, sync_hist = jax.lax.scan(block, w, (xb, yb))
        if with_history:
            obj = hinge_objective(w, x, y, c)
            acc = accuracy(w, x_cv, y_cv) if x_cv is not None else jnp.nan
            return w, (obj, acc)
        if eval_every_sync:
            # keep only the epoch-final sync stats (static shapes)
            return w, (sync_hist[0][-1], sync_hist[1][-1])
        return w, None

    w, hist = jax.lax.scan(epoch, w0, jnp.arange(epochs))
    return (w, hist) if (with_history or eval_every_sync) else w


# ---------------------------------------------------------------------------
# Algorithm 3 — DMS (distributed model synchronizing SGD)
# ---------------------------------------------------------------------------

def _shard_data(x: np.ndarray, y: np.ndarray, k: int):
    """Equal-load split across K workers (paper's load balancing)."""
    n = (x.shape[0] // k) * k
    return (x[:n].reshape(k, n // k, -1), y[:n].reshape(k, n // k))


@functools.partial(jax.jit,
                   static_argnames=("epochs", "block_size", "c", "grad_impl"))
def _dms_vmap(w0, xs, ys, *, epochs: int, block_size: int, c: float,
              grad_impl: str):
    """K simulated workers: xs (K, n_local, d). Every worker holds its own
    w between syncs; sync = mean over the worker dim after each block."""
    k, n_local, d = xs.shape
    nb = n_local // block_size
    xb = xs[:, : nb * block_size].reshape(k, nb, block_size, d)
    yb = ys[:, : nb * block_size].reshape(k, nb, block_size)
    # scan over blocks outside, vmap over workers inside
    xb = jnp.swapaxes(xb, 0, 1)   # (nb, K, bs, d)
    yb = jnp.swapaxes(yb, 0, 1)

    def epoch(w, t):
        alpha = 1.0 / (1.0 + t.astype(w.dtype))
        def block(w, xy):
            xblk, yblk = xy            # (K, bs, d), (K, bs)
            grads = jax.vmap(lambda xw, yw: block_grad(w, xw, yw, c, grad_impl)
                             )(xblk, yblk)
            w_locals = w - alpha * grads          # (K, d) per-worker models
            return jnp.mean(w_locals, axis=0), None   # MPI_AllReduce / K
        w, _ = jax.lax.scan(block, w, (xb, yb))
        return w, None

    w, _ = jax.lax.scan(epoch, w0, jnp.arange(epochs))
    return w


def _dms_shard_map(w0, xs, ys, *, epochs: int, block_size: int, c: float,
                   grad_impl: str, mesh, axis: str = "data"):
    """Real collectives: workers = mesh axis shards; sync = lax.pmean."""
    k = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    assert xs.shape[0] == k, (xs.shape, k)

    def worker(w, x_local, y_local):
        # x_local arrives as (1, n_local, d) — this worker's shard
        x_local, y_local = x_local[0], y_local[0]
        n_local, d = x_local.shape
        nb = n_local // block_size
        xb = x_local[: nb * block_size].reshape(nb, block_size, d)
        yb = y_local[: nb * block_size].reshape(nb, block_size)

        def epoch(w, t):
            alpha = 1.0 / (1.0 + t.astype(w.dtype))
            def block(w, xy):
                xblk, yblk = xy
                w_local = w - alpha * block_grad(w, xblk, yblk, c, grad_impl)
                return jax.lax.pmean(w_local, axis), None
            w, _ = jax.lax.scan(block, w, (xb, yb))
            return w, None

        w, _ = jax.lax.scan(epoch, w, jnp.arange(epochs))
        return w

    fn = jax.shard_map(worker, mesh=mesh,
                       in_specs=(P(), P(axis), P(axis)), out_specs=P(),
                       axis_names={axis}, check_vma=False)
    return jax.jit(fn)(w0, xs, ys)


def dms(w0: jax.Array, x: np.ndarray, y: np.ndarray, *, workers: int,
        epochs: int, block_size: int, c: float = 1.0,
        grad_impl: str = "jnp", backend: str = "vmap",
        mesh=None, axis: str = "data") -> jax.Array:
    """Algorithm 3 entry point. ``block_size`` is points per worker per sync
    (the paper's MSF knob: larger block ⇒ lower sync frequency)."""
    xs, ys = _shard_data(np.asarray(x), np.asarray(y), workers)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    if backend == "vmap":
        return _dms_vmap(w0, xs, ys, epochs=epochs, block_size=block_size,
                         c=c, grad_impl=grad_impl)
    if backend == "shard_map":
        assert mesh is not None
        return _dms_shard_map(w0, xs, ys, epochs=epochs, block_size=block_size,
                              c=c, grad_impl=grad_impl, mesh=mesh, axis=axis)
    raise ValueError(backend)


# ---------------------------------------------------------------------------
# instrumented variant for the paper's timing-breakdown experiments
# ---------------------------------------------------------------------------

def dms_timed_steps(mesh, axis: str, *, block_size: int, c: float = 1.0,
                    grad_impl: str = "jnp"):
    """Returns (compute_step, sync_step) jitted separately so benchmarks can
    time computation vs communication — the paper's Figs 10–12 methodology
    (they instrument around MPI_AllReduce the same way)."""

    def compute(w, xb, yb, alpha):
        # per-worker block update, NO sync. xb: (K, bs, d) sharded over axis.
        def worker(w, xw, yw):
            g = block_grad(w, xw[0], yw[0], c, grad_impl)
            return (w - alpha * g)[None]   # (1, d) → (K, d) globally
        f = jax.shard_map(worker, mesh=mesh,
                          in_specs=(P(), P(axis), P(axis)),
                          out_specs=P(axis),
                          axis_names={axis}, check_vma=False)
        return f(w, xb, yb)

    def sync(w_locals):
        def worker(wl):
            return jax.lax.pmean(wl[0], axis)
        f = jax.shard_map(worker, mesh=mesh, in_specs=(P(axis),),
                          out_specs=P(), axis_names={axis}, check_vma=False)
        return f(w_locals)

    return jax.jit(compute), jax.jit(sync)
