"""Paper-faithful SGD-SVM: Algorithms 1 (SGD), 2 (SRDMS), 3 (DMS).

Math (paper §III): hinge objective ``J = ½‖w‖² + C·Σ max(0, 1 − y⟨w,x⟩)``,
per-sample subgradient ``∇J = w`` when the margin is met, ``w − C·y·x``
otherwise, update ``w ← w − α∇J`` with ``α = 1/(1+t)`` decaying per epoch.

Block semantics (§IV-B): within a block every point computes its update from
the *same* incoming ``w`` and the block's outgoing weight is the average of
the per-point updated weights — algebraically

    w' = mean_i(w − α∇Jᵢ(w)) = w − α·mean_i(∇Jᵢ(w)),

i.e. the paper's model-synchronizing SGD is mini-batch subgradient descent
with an effective batch of ``K·s_b``. That identity is the paper's own
validation device (DMS ≡ its sequential replica) and is asserted in tests:

    DMS(K workers, block s_b)  ≡  SRDMS(block K·s_b)   (exactly, in fp64)

Three execution backends share the block math:

* :func:`seq_sgd`      — Algorithm 1, ``lax.scan`` over points.
* :func:`srdms`        — Algorithm 2, ``lax.scan`` over blocks.
* :func:`dms`          — Algorithm 3; ``backend="vmap"`` simulates K workers
  on one device (bit-identical math), ``backend="shard_map"`` runs manual
  collectives over the mesh data axis (``MPI_AllReduce`` → ``lax.pmean``).

``grad_impl="pallas"`` routes the block-gradient hot spot through the fused
Pallas kernel (:mod:`repro.kernels.hinge`).

``overlap`` lifts the sync engine's overlap modes (see
:mod:`repro.core.sync`) onto the paper-faithful path:

* ``"none"``    — blocking ``MPI_AllReduce`` at every block boundary (the
  paper; keeps the DMS ≡ SRDMS identity bit-exact).
* ``"delayed"`` — stale-by-one averaging: block *i*'s mean delta is applied
  at the end of block *i+1*, so the collective overlaps the next block's
  compute. Workers carry ``pending = meanΔ − ownΔ`` and stay within one
  block's drift of the anchor.
* ``"chunked"`` — ``w`` is split into ``chunks`` contiguous segments
  (zero-padded to equal length) and one segment is value-averaged per
  block, shrinking per-sync wire bytes ``chunks``× (each coordinate syncs
  every ``chunks`` blocks).

``topology`` lifts the sync engine's gossip axis onto the same path:

* ``"all"``      — the paper's global ``MPI_AllReduce`` (``lax.pmean``).
* ``"ring"``     — each worker averages with its two ``lax.ppermute``
  neighbors (``w ← (w + w_left + w_right)/3``): O(1) neighbor bytes per
  sync independent of K, and no global barrier for a straggler to stall.
* ``"pairwise"`` — rotating disjoint odd–even pairs average with weight ½
  (round parity alternates the pairing); requires an even worker count.

Gossip workers only reach consensus geometrically (factor λ₂ per round —
:func:`repro.core.costmodel.gossip_lambda2`); the mixing matrix is doubly
stochastic, so the worker mean is invariant and the final flush
(``mean_K(w)``) returns the exact consensus target. The ``vmap`` backend
simulates gossip with the same static mixing matrices the cost model
analyzes; the ``shard_map`` backend emits real ``ppermute``s.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def hinge_objective(w: jax.Array, x: jax.Array, y: jax.Array,
                    c: float = 1.0) -> jax.Array:
    """Paper eq. (2): ½‖w‖² + C·Σ hinge."""
    margins = 1.0 - y * (x @ w)
    return 0.5 * jnp.dot(w, w) + c * jnp.sum(jnp.maximum(0.0, margins))


def accuracy(w: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    pred = jnp.where(x @ w >= 0, 1.0, -1.0)
    return jnp.mean(pred == y)


def _padded_width(d: int, chunks: int) -> int:
    """Feature count padded up to a chunk multiple — the single source of
    the chunked carry width (``_dms_vmap`` / ``_carry_init`` /
    ``dms_stepper_init`` must agree or carries go shape-incompatible)."""
    return -(-d // chunks) * chunks


def block_grad(w: jax.Array, xb: jax.Array, yb: jax.Array, c: float,
               impl: str = "jnp") -> jax.Array:
    """Mean subgradient of a block (same incoming w for every point).

    ``∇ = w − C·mean_i(violᵢ·yᵢ·xᵢ)`` where viol = 1{1 − y⟨w,x⟩ > 0}.
    """
    if impl == "pallas":
        from repro.kernels.hinge import ops as hinge_ops
        return hinge_ops.hinge_block_grad(w, xb, yb, c)
    margins = 1.0 - yb * (xb @ w)
    viol = (margins > 0).astype(w.dtype)
    return w - c * ((viol * yb) @ xb) / xb.shape[0]


def _point_update(w, x, y, alpha, c):
    """Algorithm 1 inner step (single point)."""
    margin = 1.0 - y * jnp.dot(x, w)
    grad = jnp.where(margin > 0, w - c * y * x, w)
    return w - alpha * grad


# ---------------------------------------------------------------------------
# Algorithm 1 — sequential SGD
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("epochs", "c"))
def seq_sgd(w0: jax.Array, x: jax.Array, y: jax.Array, *, epochs: int,
            c: float = 1.0) -> jax.Array:
    def epoch(w, t):
        alpha = 1.0 / (1.0 + t.astype(w.dtype))
        def point(w, xy):
            xi, yi = xy
            return _point_update(w, xi, yi, alpha, c), None
        w, _ = jax.lax.scan(point, w, (x, y))
        return w, None
    w, _ = jax.lax.scan(epoch, w0, jnp.arange(epochs))
    return w


# ---------------------------------------------------------------------------
# Algorithm 2 — SRDMS (sequential replica of the distributed algorithm)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("epochs", "block_size", "c", "grad_impl",
                                    "with_history", "eval_every_sync"))
def srdms(w0: jax.Array, x: jax.Array, y: jax.Array, *, epochs: int,
          block_size: int, c: float = 1.0, grad_impl: str = "jnp",
          x_cv: Optional[jax.Array] = None, y_cv: Optional[jax.Array] = None,
          with_history: bool = False, eval_every_sync: bool = False):
    """Algorithm 2. Data is truncated to a whole number of blocks.

    With ``with_history`` (and cv arrays), returns per-epoch
    (objective, cv_accuracy). ``eval_every_sync=True`` reproduces the
    paper's §V-C2 methodology exactly: the cross-validation accuracy and
    objective are recomputed at EVERY model synchronization (block) — the
    per-sync overhead whose dilution with larger blocks is the paper's
    Figs 2/4 sequential-time effect.
    """
    n, d = x.shape
    nb = n // block_size
    xb = x[: nb * block_size].reshape(nb, block_size, d)
    yb = y[: nb * block_size].reshape(nb, block_size)

    def epoch(w, t):
        alpha = 1.0 / (1.0 + t.astype(w.dtype))
        def block(w, xy):
            xblk, yblk = xy
            w = w - alpha * block_grad(w, xblk, yblk, c, grad_impl)
            if eval_every_sync:
                obj = hinge_objective(w, x, y, c)
                acc = accuracy(w, x_cv, y_cv) if x_cv is not None else jnp.nan
                return w, (obj, acc)
            return w, None
        w, sync_hist = jax.lax.scan(block, w, (xb, yb))
        if with_history:
            obj = hinge_objective(w, x, y, c)
            acc = accuracy(w, x_cv, y_cv) if x_cv is not None else jnp.nan
            return w, (obj, acc)
        if eval_every_sync:
            # keep only the epoch-final sync stats (static shapes)
            return w, (sync_hist[0][-1], sync_hist[1][-1])
        return w, None

    w, hist = jax.lax.scan(epoch, w0, jnp.arange(epochs))
    return (w, hist) if (with_history or eval_every_sync) else w


# ---------------------------------------------------------------------------
# Algorithm 3 — DMS (distributed model synchronizing SGD)
# ---------------------------------------------------------------------------

def _shard_data(x: np.ndarray, y: np.ndarray, k: int):
    """Equal-load split across K workers (paper's load balancing)."""
    n = (x.shape[0] // k) * k
    return (x[:n].reshape(k, n // k, -1), y[:n].reshape(k, n // k))


@functools.partial(jax.jit,
                   static_argnames=("epochs", "block_size", "c", "grad_impl",
                                    "overlap", "chunks", "topology",
                                    "gossip_async"))
def _dms_vmap(w0, xs, ys, *, epochs: int, block_size: int, c: float,
              grad_impl: str, overlap: str = "none", chunks: int = 4,
              topology: str = "all", gossip_async: bool = False):
    """K simulated workers: xs (K, n_local, d). Every worker holds its own
    w between syncs; sync = mean over the worker dim after each block
    (blocking), stale-by-one (delayed) or one w-segment per block (chunked).
    ``topology != "all"`` replaces the worker mean with the static gossip
    mixing matrix (``w ← M w``, M from costmodel.mixing_matrices — the same
    matrices whose λ₂ the auto-tuner's guardrail reads). ``gossip_async``
    mixes the *last transmitted* snapshot instead of the current one: the
    boundary applies the carried stale correction, then banks
    ``M·(post-correction w) − w`` for the next boundary."""
    k, n_local, d = xs.shape
    nb = n_local // block_size
    xb = xs[:, : nb * block_size].reshape(k, nb, block_size, d)
    yb = ys[:, : nb * block_size].reshape(k, nb, block_size)
    # scan over blocks outside, vmap over workers inside
    xb = jnp.swapaxes(xb, 0, 1)   # (nb, K, bs, d)
    yb = jnp.swapaxes(yb, 0, 1)

    if topology != "all":
        from repro.core import costmodel
        mats = [jnp.asarray(m, w0.dtype)
                for m in costmodel.mixing_matrices(k, topology)]

        def mix(w, rnd):
            """w (K, cols) ← M_rnd w; rnd selects the pairwise parity."""
            if len(mats) == 1:
                return mats[0] @ w
            return jax.lax.cond(rnd % 2 == 0, lambda v: mats[0] @ v,
                                lambda v: mats[1] @ v, w)

        dp = _padded_width(d, chunks) if overlap == "chunked" else d
        seg = dp // chunks
        delayed = overlap == "delayed"

        def epoch(carry, t):
            alpha = 1.0 / (1.0 + t.astype(w0.dtype))

            def block(carry, xy):
                # carry: (wk, pending, cnt) under delayed/async, (wk, cnt)
                # else — the (K, dp) pending buffer only exists where read
                wk, cnt = (carry[0], carry[-1])
                xblk, yblk = xy
                grads = jax.vmap(
                    lambda ww, xw, yw: block_grad(ww[:d], xw, yw, c,
                                                  grad_impl)
                )(wk, xblk, yblk)
                w_end = wk - alpha * (grads if dp == d else
                                      jnp.pad(grads, ((0, 0), (0, dp - d))))
                if gossip_async:
                    # apply the stale correction banked at the previous
                    # boundary, then bank M·(post-correction snapshot) − it
                    # for the next one — the double-buffered exchange as a
                    # matrix recurrence (zero drift ⇒ w_t = M w_{t−1})
                    new_w = w_end + carry[1]
                    g = mix(new_w, cnt) - new_w
                    return (new_w, g, cnt + 1), None
                if overlap == "none":
                    return (mix(w_end, cnt), cnt + 1), None
                if delayed:
                    # apply the previous boundary's gossip correction; this
                    # boundary's mix feeds only the carried pending state
                    g = mix(w_end, cnt) - w_end
                    return (w_end + carry[1], g, cnt + 1), None
                rows = jax.lax.dynamic_slice(
                    w_end, (0, (cnt % chunks) * seg), (k, seg))
                mrow = mix(rows, cnt // chunks)
                w_new = jax.lax.dynamic_update_slice(
                    w_end, mrow, (0, (cnt % chunks) * seg))
                return (w_new, cnt + 1), None

            carry, _ = jax.lax.scan(block, carry, (xb, yb))
            return carry, None

        wk0 = jnp.zeros((k, dp), w0.dtype).at[:, :d].set(
            jnp.broadcast_to(w0, (k, d)))
        cnt0 = jnp.zeros((), jnp.int32)
        carry0 = ((wk0, jnp.zeros((k, dp), w0.dtype), cnt0)
                  if (delayed or gossip_async) else (wk0, cnt0))
        carry, _ = jax.lax.scan(epoch, carry0, jnp.arange(epochs))
        # flush: the worker mean is invariant under doubly stochastic
        # mixing — the exact consensus target
        return jnp.mean(carry[0], axis=0)[:d]

    if overlap == "none":
        def epoch(w, t):
            alpha = 1.0 / (1.0 + t.astype(w.dtype))
            def block(w, xy):
                xblk, yblk = xy        # (K, bs, d), (K, bs)
                grads = jax.vmap(
                    lambda xw, yw: block_grad(w, xw, yw, c, grad_impl)
                )(xblk, yblk)
                w_locals = w - alpha * grads      # (K, d) per-worker models
                return jnp.mean(w_locals, axis=0), None  # MPI_AllReduce / K
            w, _ = jax.lax.scan(block, w, (xb, yb))
            return w, None

        w, _ = jax.lax.scan(epoch, w0, jnp.arange(epochs))
        return w

    if overlap == "delayed":
        # carry: per-worker models + pending correction (meanΔ − ownΔ of the
        # previous block). This block's output never consumes this block's
        # mean — the collective has the whole next block to land.
        def epoch(carry, t):
            wk, pending = carry
            alpha = 1.0 / (1.0 + t.astype(wk.dtype))
            def block(carry, xy):
                wk, pending = carry
                xblk, yblk = xy
                grads = jax.vmap(
                    lambda ww, xw, yw: block_grad(ww, xw, yw, c, grad_impl)
                )(wk, xblk, yblk)
                delta = -alpha * grads            # (K, d) local block deltas
                mean = jnp.mean(delta, axis=0)    # the (overlappable) sync
                return (wk + delta + pending, mean[None] - delta), None
            carry, _ = jax.lax.scan(block, (wk, pending), (xb, yb))
            return carry, None

        carry0 = (jnp.broadcast_to(w0, (k, d)), jnp.zeros((k, d), w0.dtype))
        (wk, _), _ = jax.lax.scan(epoch, carry0, jnp.arange(epochs))
        # flush: workers sit at anchor + ownΔ_last; their mean is the fully
        # synchronized model anchor + meanΔ_last
        return jnp.mean(wk, axis=0)

    if overlap == "chunked":
        dp = _padded_width(d, chunks)
        seg = dp // chunks
        def epoch(carry, t):
            alpha = 1.0 / (1.0 + t.astype(w0.dtype))
            def block(carry, xy):
                wk, cnt = carry                   # (K, dp), i32
                xblk, yblk = xy
                grads = jax.vmap(
                    lambda ww, xw, yw: block_grad(ww[:d], xw, yw, c, grad_impl)
                )(wk, xblk, yblk)
                w_end = wk - alpha * jnp.pad(grads, ((0, 0), (0, dp - d)))
                idx = cnt % chunks
                rows = jax.lax.dynamic_slice(w_end, (0, idx * seg), (k, seg))
                mrow = jnp.broadcast_to(jnp.mean(rows, axis=0), (k, seg))
                w_new = jax.lax.dynamic_update_slice(w_end, mrow,
                                                     (0, idx * seg))
                return (w_new, cnt + 1), None
            carry, _ = jax.lax.scan(block, carry, (xb, yb))
            return carry, None

        wk0 = jnp.zeros((k, dp), w0.dtype).at[:, :d].set(
            jnp.broadcast_to(w0, (k, d)))
        carry0 = (wk0, jnp.zeros((), jnp.int32))
        (wk, _), _ = jax.lax.scan(epoch, carry0, jnp.arange(epochs))
        return jnp.mean(wk, axis=0)[:d]

    raise ValueError(f"unknown overlap mode: {overlap!r}")


def _make_worker_block(axis: str, *, c: float, grad_impl: str, overlap: str,
                       chunks: int, d: int, topology: str = "all",
                       gossip_async: bool = False):
    """One worker's block (compute + boundary sync), inside shard_map with
    ``axis`` manual. ``carry`` is a dict per overlap mode:

        none:    {"w": (d,)}                    — replicated after each sync
        delayed: {"w": (d,), "pending": (d,)}   — pending = meanΔ − ownΔ
        chunked: {"w": (dp,), "cnt": i32}       — dp = d padded to chunks·seg

    ``topology != "all"`` swaps every ``pmean`` for a ``ppermute`` neighbor
    mix (:func:`repro.core.sync.gossip_mix`); ``"pairwise"`` adds a ``cnt``
    round counter to the none/delayed carries for the pairing parity, and
    the delayed pending becomes ``mix(w_end) − w_end`` (value-form gossip —
    workers never share an anchor, so a delta-only exchange would let the
    anchors drift apart unboundedly).

    Under ``delayed`` the returned ``w`` depends only on the *previous*
    boundary's correction; this boundary's collective output feeds only
    ``pending``, so it is not on this or the next block's compute critical
    path.

    ``gossip_async`` (gossip only, ``overlap="none"``) double-buffers the
    exchange: carry gains ``sent``/``mixbuf`` (the snapshot transmitted at
    the previous boundary and the neighbor payloads received there); the
    boundary applies the stale correction ``mixbuf + M_ii·sent − sent``
    first, then ppermutes the post-correction model into the buffers for
    the *next* boundary — a worker never consumes a neighbor's
    current-round value.
    """
    from repro.core import sync as _sync
    gossip = topology != "all"
    if gossip_async:
        assert gossip and overlap == "none", (topology, overlap)

    def exchange(v, cnt):
        """Boundary exchange: global mean, or topology neighbor mix."""
        if gossip:
            return _sync.gossip_mix(v, axis, topology, round_idx=cnt)
        return jax.lax.pmean(v, axis)

    def bump(out, carry):
        if gossip and topology == "pairwise" and overlap != "chunked":
            out["cnt"] = carry["cnt"] + 1
        return out

    def block(carry, xblk, yblk, alpha):
        cnt = carry.get("cnt")
        if gossip_async:
            w = carry["w"]
            w_self = _sync.gossip_self_weight(topology)
            w_end = w - alpha * block_grad(w, xblk, yblk, c, grad_impl)
            new_w = (w_end + carry["mixbuf"]
                     + (w_self - 1.0) * carry["sent"])
            recv = _sync.gossip_recv(new_w, axis, topology, round_idx=cnt)
            return bump({"w": new_w, "sent": new_w, "mixbuf": recv}, carry)
        if overlap == "none":
            w = carry["w"]
            w_local = w - alpha * block_grad(w, xblk, yblk, c, grad_impl)
            return bump({"w": exchange(w_local, cnt)}, carry)
        if overlap == "delayed":
            w = carry["w"]
            delta = -alpha * block_grad(w, xblk, yblk, c, grad_impl)
            w_end = w + delta
            if gossip:
                pending = exchange(w_end, cnt) - w_end   # overlappable
            else:
                pending = jax.lax.pmean(delta, axis) - delta
            return bump({"w": w_end + carry["pending"],
                         "pending": pending}, carry)
        # chunked: one w-segment value-exchanged per block
        w = carry["w"]                               # (dp,)
        dp = w.shape[0]
        seg = dp // chunks
        g = block_grad(w[:d], xblk, yblk, c, grad_impl)
        w_end = w - alpha * jnp.pad(g, (0, dp - d))
        idx = carry["cnt"] % chunks
        row = jax.lax.dynamic_slice(w_end, (idx * seg,), (seg,))
        row = exchange(row, carry["cnt"] // chunks)  # 1/chunks of the bytes
        w_new = jax.lax.dynamic_update_slice(w_end, row, (idx * seg,))
        return {"w": w_new, "cnt": carry["cnt"] + 1}
    return block


def _needs_round(overlap: str, topology: str) -> bool:
    """Pairwise none/delayed carries a round counter for the pairing parity
    (chunked reuses its own cnt)."""
    return topology == "pairwise" and overlap != "chunked"


def _carry_init(w0, *, overlap: str, chunks: int, topology: str = "all",
                gossip_async: bool = False):
    """Initial per-worker carry (local, no leading worker dim)."""
    d = w0.shape[0]
    if gossip_async:
        sent, mixbuf = dms_async_buffers_init(w0, topology)
        carry = {"w": w0, "sent": sent, "mixbuf": mixbuf}
    elif overlap == "none":
        carry = {"w": w0}
    elif overlap == "delayed":
        carry = {"w": w0, "pending": jnp.zeros((d,), w0.dtype)}
    else:
        dp = _padded_width(d, chunks)
        carry = {"w": jnp.zeros((dp,), w0.dtype).at[:d].set(w0),
                 "cnt": jnp.zeros((), jnp.int32)}
    if _needs_round(overlap, topology):
        carry["cnt"] = jnp.zeros((), jnp.int32)
    return carry


def _carry_flush(carry, axis: str, *, overlap: str, d: int,
                 topology: str = "all"):
    """Collapse a worker's carry to the fully synchronized model."""
    if overlap == "none" and topology == "all":
        return carry["w"]
    if overlap in ("none", "delayed"):
        # workers sit within one block's drift (delayed) or the gossip
        # consensus envelope; their mean is the synchronized model (the
        # mean is invariant under the doubly stochastic gossip mix)
        return jax.lax.pmean(carry["w"], axis)
    return jax.lax.pmean(carry["w"], axis)[:d]


def _dms_shard_map(w0, xs, ys, *, epochs: int, block_size: int, c: float,
                   grad_impl: str, mesh, axis: str = "data",
                   overlap: str = "none", chunks: int = 4,
                   topology: str = "all", gossip_async: bool = False):
    """Real collectives: workers = mesh axis shards; sync = lax.pmean
    (``topology="all"``) or lax.ppermute neighbor mixing (gossip)."""
    k = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    assert xs.shape[0] == k, (xs.shape, k)
    d = w0.shape[0]

    def worker(w, x_local, y_local):
        # x_local arrives as (1, n_local, d) — this worker's shard
        x_local, y_local = x_local[0], y_local[0]
        n_local, _ = x_local.shape
        nb = n_local // block_size
        xb = x_local[: nb * block_size].reshape(nb, block_size, d)
        yb = y_local[: nb * block_size].reshape(nb, block_size)
        blockfn = _make_worker_block(axis, c=c, grad_impl=grad_impl,
                                     overlap=overlap, chunks=chunks, d=d,
                                     topology=topology,
                                     gossip_async=gossip_async)

        def epoch(carry, t):
            alpha = 1.0 / (1.0 + t.astype(w.dtype))
            def blk(carry, xy):
                return blockfn(carry, xy[0], xy[1], alpha), None
            carry, _ = jax.lax.scan(blk, carry, (xb, yb))
            return carry, None

        carry, _ = jax.lax.scan(epoch, _carry_init(w, overlap=overlap,
                                                   chunks=chunks,
                                                   topology=topology,
                                                   gossip_async=gossip_async),
                                jnp.arange(epochs))
        return _carry_flush(carry, axis, overlap=overlap, d=d,
                            topology=topology)

    fn = jax.shard_map(worker, mesh=mesh,
                       in_specs=(P(), P(axis), P(axis)), out_specs=P(),
                       axis_names={axis}, check_vma=False)
    return jax.jit(fn)(w0, xs, ys)


def dms(w0: jax.Array, x: np.ndarray, y: np.ndarray, *, workers: int,
        epochs: int, block_size: int, c: float = 1.0,
        grad_impl: str = "jnp", backend: str = "vmap",
        mesh=None, axis: str = "data", overlap: str = "none",
        chunks: int = 4, topology: str = "all",
        gossip_async: bool = False) -> jax.Array:
    """Algorithm 3 entry point. ``block_size`` is points per worker per sync
    (the paper's MSF knob: larger block ⇒ lower sync frequency);
    ``overlap`` ∈ {"none", "delayed", "chunked"} selects how the residual
    sync is taken off the critical path and ``topology`` ∈ {"all", "ring",
    "pairwise"} which workers it couples (module docstring);
    ``gossip_async`` switches a gossip topology to the double-buffered
    unsynchronized-round exchange (requires ``overlap="none"``)."""
    if gossip_async and (topology == "all" or overlap != "none"):
        raise ValueError("gossip_async needs a gossip topology and "
                         f"overlap='none'; got topology={topology!r}, "
                         f"overlap={overlap!r}")
    xs, ys = _shard_data(np.asarray(x), np.asarray(y), workers)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    if backend == "vmap":
        return _dms_vmap(w0, xs, ys, epochs=epochs, block_size=block_size,
                         c=c, grad_impl=grad_impl, overlap=overlap,
                         chunks=chunks, topology=topology,
                         gossip_async=gossip_async)
    if backend == "shard_map":
        assert mesh is not None
        return _dms_shard_map(w0, xs, ys, epochs=epochs, block_size=block_size,
                              c=c, grad_impl=grad_impl, mesh=mesh, axis=axis,
                              overlap=overlap, chunks=chunks,
                              topology=topology, gossip_async=gossip_async)
    raise ValueError(backend)


# ---------------------------------------------------------------------------
# instrumented variant for the paper's timing-breakdown experiments
# ---------------------------------------------------------------------------

def dms_timed_steps(mesh, axis: str, *, block_size: int, c: float = 1.0,
                    grad_impl: str = "jnp", overlap: str = "none",
                    chunks: int = 4, topology: str = "all",
                    gossip_async: bool = False, telemetry=None):
    """Returns (compute_step, sync_step) jitted separately so benchmarks can
    time computation vs communication — the paper's Figs 10–12 methodology
    (they instrument around MPI_AllReduce the same way).

    ``telemetry`` (a :class:`repro.core.telemetry.BlockTelemetry`) wraps
    both returned steps with host-side timers: each compute call records
    ``block_size`` steps' compute time, each sync call one collective —
    the separated T_step/T_sync feed the MSF auto-tuner's adaptive
    controller and calibrate the simsync cluster simulator.

    ``overlap`` changes the sync step's signature (compute is unchanged —
    per-worker block update from per-worker models):

        none:    sync(w_locals) → w                       (blocking pmean)
        delayed: sync(w_start_locals, w_end_locals, pending)
                     → (w_new_locals, new_pending)        (stale-by-one)
        chunked: sync(w_end_locals, cnt) → w_new_locals   (one segment;
                 d must be divisible by ``chunks``; caller increments cnt)

    ``topology != "all"`` (supported for ``overlap="none"``) swaps the
    blocking pmean for the gossip neighbor mix; models stay per-worker:

        gossip:  sync(w_locals, cnt) → w_new_locals       (ppermute mix)
        async:   sync(w_locals, sent, mixbuf, cnt)
                     → (w_new_locals, new_sent, new_mixbuf)
                 (apply the stale correction, then the double-buffered
                  ppermute half-exchange; seed sent/mixbuf with
                  ``dms_async_buffers_init``)
    """
    gossip = topology != "all"
    if gossip and overlap != "none":
        raise ValueError("dms_timed_steps times gossip only for "
                         "overlap='none' (use dms_block_stepper otherwise)")
    if gossip_async and not gossip:
        raise ValueError("gossip_async needs topology='ring'/'pairwise'")

    def compute(w, xb, yb, alpha):
        # per-worker block update, NO sync. xb: (K, bs, d) sharded over axis.
        # w: replicated (d,) for blocking topology="all", per-worker (K, d)
        # otherwise (gossip never re-replicates the model).
        replicated_w = overlap == "none" and not gossip
        w_spec = P() if replicated_w else P(axis)
        def worker(w, xw, yw):
            wl = w if replicated_w else w[0]
            g = block_grad(wl, xw[0], yw[0], c, grad_impl)
            return (wl - alpha * g)[None]   # (1, d) → (K, d) globally
        f = jax.shard_map(worker, mesh=mesh,
                          in_specs=(w_spec, P(axis), P(axis)),
                          out_specs=P(axis),
                          axis_names={axis}, check_vma=False)
        return f(w, xb, yb)

    if gossip_async:
        from repro.core import sync as _sync
        w_self = _sync.gossip_self_weight(topology)

        def sync(w_locals, sent, mixbuf, cnt):
            def worker(wl, sl, bl, cnt):
                new_w = wl[0] + bl[0] + (w_self - 1.0) * sl[0]
                recv = _sync.gossip_recv(new_w, axis, topology,
                                         round_idx=cnt)
                return new_w[None], new_w[None], recv[None]
            f = jax.shard_map(worker, mesh=mesh,
                              in_specs=(P(axis), P(axis), P(axis), P()),
                              out_specs=(P(axis), P(axis), P(axis)),
                              axis_names={axis}, check_vma=False)
            return f(w_locals, sent, mixbuf, cnt)
    elif gossip:
        from repro.core import sync as _sync

        def sync(w_locals, cnt):
            def worker(wl, cnt):
                return _sync.gossip_mix(wl[0], axis, topology,
                                        round_idx=cnt)[None]
            f = jax.shard_map(worker, mesh=mesh, in_specs=(P(axis), P()),
                              out_specs=P(axis), axis_names={axis},
                              check_vma=False)
            return f(w_locals, cnt)
    elif overlap == "none":
        def sync(w_locals):
            def worker(wl):
                return jax.lax.pmean(wl[0], axis)
            f = jax.shard_map(worker, mesh=mesh, in_specs=(P(axis),),
                              out_specs=P(), axis_names={axis},
                              check_vma=False)
            return f(w_locals)
    elif overlap == "delayed":
        def sync(w_start_locals, w_end_locals, pending):
            def worker(ws, we, pend):
                delta = we[0] - ws[0]
                mean = jax.lax.pmean(delta, axis)
                return (we[0] + pend[0])[None], (mean - delta)[None]
            f = jax.shard_map(worker, mesh=mesh,
                              in_specs=(P(axis), P(axis), P(axis)),
                              out_specs=(P(axis), P(axis)),
                              axis_names={axis}, check_vma=False)
            return f(w_start_locals, w_end_locals, pending)
    elif overlap == "chunked":
        def sync(w_end_locals, cnt):
            d = w_end_locals.shape[-1]
            assert d % chunks == 0, (d, chunks)
            seg = d // chunks
            def worker(we, cnt):
                w = we[0]
                idx = cnt % chunks
                row = jax.lax.dynamic_slice(w, (idx * seg,), (seg,))
                row = jax.lax.pmean(row, axis)
                return jax.lax.dynamic_update_slice(w, row, (idx * seg,))[None]
            f = jax.shard_map(worker, mesh=mesh, in_specs=(P(axis), P()),
                              out_specs=P(axis), axis_names={axis},
                              check_vma=False)
            return f(w_end_locals, cnt)
    else:
        raise ValueError(f"unknown overlap mode: {overlap!r}")

    compute_jit, sync_jit = jax.jit(compute), jax.jit(sync)
    if telemetry is None:
        return compute_jit, sync_jit

    import time as _time

    def timed_compute(*args):
        t0 = _time.perf_counter()
        out = compute_jit(*args)
        jax.block_until_ready(out)
        telemetry.record_step_time(_time.perf_counter() - t0,
                                   steps=block_size)
        return out

    def timed_sync(*args):
        t0 = _time.perf_counter()
        out = sync_jit(*args)
        jax.block_until_ready(out)
        telemetry.record_sync_time(_time.perf_counter() - t0)
        return out

    return timed_compute, timed_sync


def dms_async_buffers_init(w_locals: jax.Array, topology: str):
    """Seed ``(sent, mixbuf)`` for the async carries and timed-sync path —
    the engine's zero-first-correction seed (one shared definition, see
    :func:`repro.core.sync.init_async_buffers`)."""
    from repro.core import sync as _sync
    return _sync.init_async_buffers(w_locals, topology)


# ---------------------------------------------------------------------------
# single-block stepper — the unit the overlap benchmark times and the
# jaxpr/HLO overlap test inspects
# ---------------------------------------------------------------------------

def dms_stepper_init(w0: jax.Array, workers: int, *, overlap: str = "none",
                     chunks: int = 4, topology: str = "all",
                     gossip_async: bool = False):
    """Global (stacked) initial carry for :func:`dms_block_stepper`."""
    d = w0.shape[0]
    wk = jnp.broadcast_to(w0, (workers, d))
    if gossip_async:
        sent, mixbuf = dms_async_buffers_init(wk, topology)
        carry = {"w": wk, "sent": sent, "mixbuf": mixbuf}
    elif overlap == "none":
        carry = {"w": wk}
    elif overlap == "delayed":
        carry = {"w": wk, "pending": jnp.zeros((workers, d), w0.dtype)}
    elif overlap == "chunked":
        dp = _padded_width(d, chunks)
        wp = jnp.zeros((workers, dp), w0.dtype).at[:, :d].set(wk)
        carry = {"w": wp, "cnt": jnp.zeros((), jnp.int32)}
    else:
        raise ValueError(f"unknown overlap mode: {overlap!r}")
    if _needs_round(overlap, topology):
        carry["cnt"] = jnp.zeros((), jnp.int32)
    return carry


def dms_block_stepper(mesh, axis: str, *, d: int, c: float = 1.0,
                      grad_impl: str = "jnp", overlap: str = "none",
                      chunks: int = 4, topology: str = "all",
                      gossip_async: bool = False):
    """One DMS block (compute + boundary sync) as a jittable step:

        step(carry, xblk, yblk, alpha) → carry

    with ``carry`` from :func:`dms_stepper_init` (leaves carry a leading
    worker dim sharded over ``axis``; ``cnt`` is replicated) and ``xblk``
    (K, bs, d) / ``yblk`` (K, bs) sharded over ``axis``. Not jitted — wrap
    in ``jax.jit``/``lax.scan`` for timing, or ``jax.make_jaxpr`` to verify
    the overlap property (delayed: no dot depends on the block's pmean), the
    gossip property (ring/pairwise: ppermutes only, no global collective),
    or the async property (``gossip_async``: the ppermute output feeds only
    the carried ``sent``/``mixbuf`` buffers — no dot in this *or* the next
    block consumes it).
    """
    blockfn = _make_worker_block(axis, c=c, grad_impl=grad_impl,
                                 overlap=overlap, chunks=chunks, d=d,
                                 topology=topology,
                                 gossip_async=gossip_async)
    cspec = {"w": P(axis)}
    if gossip_async:
        cspec["sent"] = P(axis)
        cspec["mixbuf"] = P(axis)
    if overlap == "delayed":
        cspec["pending"] = P(axis)
    if overlap == "chunked" or _needs_round(overlap, topology):
        cspec["cnt"] = P()

    def step(carry, xblk, yblk, alpha):
        def worker(carry, xw, yw):
            local = {k: (v if k == "cnt" else v[0]) for k, v in carry.items()}
            out = blockfn(local, xw[0], yw[0], alpha)
            return {k: (v if k == "cnt" else v[None]) for k, v in out.items()}
        f = jax.shard_map(worker, mesh=mesh,
                          in_specs=(cspec, P(axis), P(axis)),
                          out_specs=cspec,
                          axis_names={axis}, check_vma=False)
        return f(carry, xblk, yblk)

    return step


def dms_block_ladder(mesh, axis: str, *, d: int, workers: int, block_sizes,
                     c: float = 1.0, grad_impl: str = "jnp",
                     overlap: str = "none", chunks: int = 4,
                     topology: str = "all", gossip_async: bool = False,
                     dtype=jnp.float32):
    """Pre-compiled block-size ladder for the SVM path — the DMS analog of
    the LM trainer's H-ladder (:mod:`repro.runtime.ladder`).

    One :func:`dms_block_stepper` is traced once (its carry layout is
    block-size independent) and AOT-compiled for every ``bs`` in
    ``block_sizes``: ``{bs: compiled}`` where ``compiled(carry, xblk,
    yblk, alpha)`` expects ``xblk (K, bs, d)`` / ``yblk (K, bs)`` and can
    never retrace or recompile (a shape mismatch raises). A mid-run MSF
    move is :func:`dms_ladder_switch` on the carry + picking another
    rung + re-blocking the data stream.
    """
    step = dms_block_stepper(mesh, axis, d=d, c=c, grad_impl=grad_impl,
                             overlap=overlap, chunks=chunks,
                             topology=topology, gossip_async=gossip_async)
    jitted = jax.jit(step)
    carry = dms_stepper_init(jnp.zeros((d,), dtype), workers,
                             overlap=overlap, chunks=chunks,
                             topology=topology, gossip_async=gossip_async)
    carry_avals = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), carry)
    alpha_aval = jax.ShapeDtypeStruct((), dtype)
    out = {}
    for bs in sorted(set(int(b) for b in block_sizes)):
        x_aval = jax.ShapeDtypeStruct((workers, bs, d), dtype)
        y_aval = jax.ShapeDtypeStruct((workers, bs), dtype)
        out[bs] = jitted.lower(carry_avals, x_aval, y_aval,
                               alpha_aval).compile()
    return out


def dms_ladder_switch(carry, *, overlap: str = "none", chunks: int = 4,
                      topology: str = "all", gossip_async: bool = False,
                      d: Optional[int] = None):
    """Exact carry for resuming DMS at a different block size (host-level,
    stacked carry from :func:`dms_stepper_init`/:func:`dms_block_stepper`).

    Collapses the carry to the flushed model — delayed folds the pending
    correction first, then the worker mean (exact: workers are identical
    under blocking ``topology="all"``; within one block's drift under
    delayed; and the mean is the invariant consensus target under any
    gossip topology, chunked staleness included) — and re-seeds a fresh
    carry at that model via :func:`dms_stepper_init`. By construction the
    result is bit-identical to a fresh ladder start from the flushed
    weights, which is the ladder-switch exactness the tests assert.
    """
    wk = carry["w"].astype(jnp.float32)
    if overlap == "delayed":
        wk = wk + carry["pending"].astype(jnp.float32)
    w = jnp.mean(wk, axis=0)
    if overlap == "chunked" and d is not None:
        w = w[:d]
    workers = carry["w"].shape[0]
    return dms_stepper_init(w.astype(carry["w"].dtype), workers,
                            overlap=overlap, chunks=chunks,
                            topology=topology, gossip_async=gossip_async)
