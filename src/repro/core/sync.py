"""Model-synchronization engine — the paper's contribution as a library.

The paper's finding: the *frequency* of model synchronization (MSF) is a
free knob — accuracy is flat across block sizes while communication cost
scales as ``1/H`` — so sync schedule should be a first-class config, not an
implementation detail. This module turns :class:`repro.config.SyncConfig`
into the sync-point transformation applied inside the compiled train block:

    sync_point(params_start, params_end, sync_state, cfg, axis)
        → (new_params, new_sync_state)

Semantics per strategy (all reduce over the *replica* mesh axis):

* ``sync_every_step`` — no replica axis at all; gradients are averaged by
  XLA's data-parallel partitioning every step (paper's MSF=1 analog). The
  sync engine is bypassed; provided here only for config completeness.
* ``periodic`` — parameter averaging every H local steps (paper's DMS):
  ``w ← mean_K(w_local)``, realized as ``w_start + mean_K(delta)``.
* ``hierarchical`` — same as periodic but the replica axis is the *pod*
  (DCN) axis while the intra-pod data axis still syncs every step — the
  TPU-native placement of the paper's optimization (apply MSF to the
  slowest link).

Optional modifiers (beyond-paper, composable):

* ``compression="int8"`` — error-feedback int8 delta exchange
  (:mod:`repro.core.compression`), shrinking the sync collective 4×.
* ``slowmo > 0`` — outer momentum on the averaged delta (SlowMo, Wang et
  al.): recovers accuracy at very low MSF; state is one replicated
  momentum pytree.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import SyncConfig
from repro.core import compression as C


def needs_replica_axis(cfg: SyncConfig) -> bool:
    return cfg.strategy in ("periodic", "hierarchical")


def init_sync_state(cfg: SyncConfig, params) -> Dict[str, Any]:
    state: Dict[str, Any] = {}
    if cfg.compression in ("int8", "int16"):
        state["ef"] = C.init_error_feedback(params)
    if cfg.slowmo > 0.0:
        state["slowmo_m"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def sync_state_axes(cfg: SyncConfig, param_axes) -> Dict[str, Any]:
    """Logical-axes tree matching init_sync_state (mirrors params)."""
    state: Dict[str, Any] = {}
    if cfg.compression in ("int8", "int16"):
        state["ef"] = param_axes
    if cfg.slowmo > 0.0:
        state["slowmo_m"] = param_axes
    return state


def sync_point(params_start, params_end, sync_state: Dict[str, Any],
               cfg: SyncConfig, axis: str,
               param_axes=None) -> Tuple[Any, Dict[str, Any]]:
    """One model synchronization, inside shard_map with ``axis`` manual.

    ``params_start`` — the (identical-across-replicas) params the block
    started from; ``params_end`` — this replica's drifted params.
    ``param_axes`` — per-leaf logical axes (keeps the compressed-sync
    buffers sharded; see compression.allgather_mean_dequant).
    """
    delta = jax.tree.map(
        lambda e, s: e.astype(jnp.float32) - s.astype(jnp.float32),
        params_end, params_start)
    new_state = dict(sync_state)

    if cfg.compression == "int8":
        q, s, new_ef = C.compress_tree(delta, sync_state["ef"])
        mean_delta = C.allgather_mean_dequant(q, s, axis, param_axes)
        new_state["ef"] = new_ef
    elif cfg.compression == "int16":
        # fixed-point 2-byte wire via an ordinary (shape-preserving)
        # all-reduce: a psum of int16 composes cleanly with auto-axis
        # sharding, where the int8 all-gather materializes full leaves
        # per device and a bf16 pmean trips XLA's AllReducePromotion
        # CHECK (§Perf C-cell log). A shared per-tensor scale is agreed
        # via pmax first; 14-bit mantissa beats bf16's 8 at the same
        # wire width. Rounding error is carried in the EF buffer.
        def int16_leaf(d, e):
            v = d + e
            amax = jax.lax.pmax(jnp.max(jnp.abs(v)), axis)
            # headroom so K replicas sum within int16 range
            scale = jnp.maximum(amax, 1e-12) / 8192.0
            q = jnp.clip(jnp.round(v / scale), -8192, 8192
                         ).astype(jnp.int16)
            summed = jax.lax.psum(q, axis).astype(jnp.float32)
            mean = summed * scale / jax.lax.psum(1, axis)
            return mean, v - q.astype(jnp.float32) * scale
        out = jax.tree.map(int16_leaf, delta, sync_state["ef"])
        is_t = lambda x: isinstance(x, tuple)
        mean_delta = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
        new_state["ef"] = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
    else:
        mean_delta = jax.tree.map(lambda d: jax.lax.pmean(d, axis), delta)

    if cfg.slowmo > 0.0:
        m = jax.tree.map(
            lambda mm, d: cfg.slowmo * mm + d, sync_state["slowmo_m"], mean_delta)
        new_state["slowmo_m"] = m
        step_delta = jax.tree.map(lambda mm: cfg.slowmo_lr * mm, m)
    else:
        step_delta = mean_delta

    new_params = jax.tree.map(
        lambda s, d: (s.astype(jnp.float32) + d).astype(s.dtype),
        params_start, step_delta)
    return new_params, new_state


def collective_bytes_per_sync(param_bytes: int, world: int, cfg: SyncConfig) -> int:
    """Analytic wire bytes of one sync (for napkin math / benchmarks).

    Ring all-reduce moves ``2·P·(K-1)/K`` bytes per device; int8 all-gather
    moves ``P/4·(K-1)`` per device (fp32 accounting).
    """
    if cfg.compression == "int8":
        return int(param_bytes / 4 * (world - 1))
    if cfg.compression == "int16":
        return int(2 * param_bytes / 4 * 2 * (world - 1) / world)
    return int(2 * param_bytes * (world - 1) / world)


def amortized_bytes_per_step(param_bytes: int, world: int, cfg: SyncConfig) -> float:
    if cfg.strategy == "sync_every_step":
        return collective_bytes_per_sync(param_bytes, world, cfg)
    return collective_bytes_per_sync(param_bytes, world, cfg) / max(1, cfg.period)
