"""Model-synchronization engine — the paper's contribution as a library.

The paper's finding: the *frequency* of model synchronization (MSF) is a
free knob — accuracy is flat across block sizes while communication cost
scales as ``1/H`` — so sync schedule should be a first-class config, not an
implementation detail. This module turns :class:`repro.config.SyncConfig`
into the sync-point transformation applied inside the compiled train block:

    sync_point(params_start, params_end, sync_state, cfg, axis)
        → (new_params, new_sync_state)

Strategy × overlap matrix (all reduce over the *replica* mesh axis):

=================  ==========================================================
``strategy``       when the sync point runs
=================  ==========================================================
sync_every_step    never (XLA's data-parallel grad all-reduce every step;
                   the engine is bypassed — config completeness only)
periodic           every H local steps (paper's DMS): ``w ← mean_K(w_local)``
hierarchical       as periodic, but the replica axis is the *pod* (DCN) axis
                   while the intra-pod data axis still syncs every step
=================  ==========================================================

=================  ==========================================================
``overlap``        what the sync point does when it runs
=================  ==========================================================
none               blocking: ``w ← w_start + mean_K(Δ)`` at the boundary —
                   the paper's semantics, bit-exact DMS ≡ SRDMS
delayed            stale-by-one: block *i* computes ``mean_K(Δᵢ)`` but the
                   result is applied at the end of block *i+1*; this block's
                   params depend only on the *previous* mean, so the
                   collective is free to run under block *i+1*'s compute.
                   Each replica's params stay ``anchor + own latest Δ``;
                   divergence is bounded by one block's local drift
                   (Stich 2018's local-SGD staleness regime)
chunked            partial: the parameter tree is split into ``cfg.chunks``
                   byte-balanced shards (equal-size leaves round-robin) and
                   one shard is value-averaged per block
                   (``w_leaf ← mean_K(w_leaf)``); each leaf syncs every
                   ``chunks·H`` steps and per-sync wire bytes shrink
                   ``chunks``×
=================  ==========================================================

Optional modifiers (beyond-paper, composable):

* ``compression="int8"`` — error-feedback int8 delta exchange
  (:mod:`repro.core.compression`), shrinking the sync collective 4×.
* ``compression="int16"`` — fixed-point 2-byte all-reduce wire.
* ``slowmo > 0`` — outer momentum on the averaged delta (SlowMo, Wang et
  al.); composes with ``overlap="delayed"`` (the momentum step is taken on
  the freshly averaged delta, applied one block late), not with
  ``"chunked"`` (no whole-tree delta to step on).

Byte accounting lives in :mod:`repro.core.costmodel` (shared with the MSF
auto-tuner so the two can never drift).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import SyncConfig
from repro.core import compression as C
from repro.core import costmodel


def needs_replica_axis(cfg: SyncConfig) -> bool:
    return cfg.strategy in ("periodic", "hierarchical")


def validate(cfg: SyncConfig) -> None:
    if cfg.overlap not in ("none", "delayed", "chunked"):
        raise ValueError(f"unknown overlap mode: {cfg.overlap!r}")
    if cfg.overlap == "chunked" and cfg.slowmo > 0.0:
        raise ValueError("slowmo requires a whole-tree sync delta; "
                         "overlap='chunked' averages one shard at a time")
    if cfg.overlap == "chunked" and cfg.chunks < 1:
        raise ValueError(f"chunks must be >= 1, got {cfg.chunks}")


def init_sync_state(cfg: SyncConfig, params) -> Dict[str, Any]:
    validate(cfg)
    state: Dict[str, Any] = {}
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if cfg.compression in ("int8", "int16"):
        state["ef"] = C.init_error_feedback(params)
    if cfg.slowmo > 0.0:
        state["slowmo_m"] = zeros()
    if cfg.overlap == "delayed":
        # pending correction = (averaged step delta − own local delta) of the
        # previous block; applied to this block's end params (stale-by-one)
        state["pending"] = zeros()
    if cfg.overlap == "chunked":
        state["chunk_idx"] = jnp.zeros((), jnp.int32)
    return state


def sync_state_axes(cfg: SyncConfig, param_axes) -> Dict[str, Any]:
    """Logical-axes tree matching init_sync_state (mirrors params)."""
    state: Dict[str, Any] = {}
    if cfg.compression in ("int8", "int16"):
        state["ef"] = param_axes
    if cfg.slowmo > 0.0:
        state["slowmo_m"] = param_axes
    if cfg.overlap == "delayed":
        state["pending"] = param_axes
    if cfg.overlap == "chunked":
        state["chunk_idx"] = ()
    return state


# ---------------------------------------------------------------------------
# the mean-exchange primitive (shared by every overlap mode)
# ---------------------------------------------------------------------------

def _exchange_mean(values, ef, cfg: SyncConfig, axis: str, param_axes):
    """Replica-mean of a pytree over ``axis`` under cfg.compression.

    Returns ``(mean_tree, new_ef_tree_or_None)``. ``values`` may be deltas
    (blocking/delayed) or raw parameter values (chunked); error feedback
    carries the quantization residual either way.
    """
    if cfg.compression == "int8":
        q, s, new_ef = C.compress_tree(values, ef)
        return C.allgather_mean_dequant(q, s, axis, param_axes), new_ef
    if cfg.compression == "int16":
        # fixed-point 2-byte wire via an ordinary (shape-preserving)
        # all-reduce: a psum of int16 composes cleanly with auto-axis
        # sharding, where the int8 all-gather materializes full leaves
        # per device and a bf16 pmean trips XLA's AllReducePromotion
        # CHECK (§Perf C-cell log). A shared per-tensor scale is agreed
        # via pmax first; 14-bit mantissa beats bf16's 8 at the same
        # wire width. Rounding error is carried in the EF buffer.
        def int16_leaf(d, e):
            v = d + e
            amax = jax.lax.pmax(jnp.max(jnp.abs(v)), axis)
            # headroom so K replicas sum within int16 range
            scale = jnp.maximum(amax, 1e-12) / 8192.0
            q = jnp.clip(jnp.round(v / scale), -8192, 8192
                         ).astype(jnp.int16)
            summed = jax.lax.psum(q, axis).astype(jnp.float32)
            mean = summed * scale / jax.lax.psum(1, axis)
            return mean, v - q.astype(jnp.float32) * scale
        out = jax.tree.map(int16_leaf, values, ef)
        is_t = lambda x: isinstance(x, tuple)
        mean = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
        new_ef = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
        return mean, new_ef
    return jax.tree.map(lambda d: jax.lax.pmean(d, axis), values), None


def _slowmo_step(mean_delta, sync_state, new_state, cfg: SyncConfig):
    """Outer momentum on the averaged delta; returns the applied delta."""
    if cfg.slowmo <= 0.0:
        return mean_delta
    m = jax.tree.map(lambda mm, d: cfg.slowmo * mm + d,
                     sync_state["slowmo_m"], mean_delta)
    new_state["slowmo_m"] = m
    return jax.tree.map(lambda mm: cfg.slowmo_lr * mm, m)


def _f32_delta(params_end, params_start):
    return jax.tree.map(
        lambda e, s: e.astype(jnp.float32) - s.astype(jnp.float32),
        params_end, params_start)


def _apply_f32(params, delta):
    return jax.tree.map(
        lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype),
        params, delta)


# ---------------------------------------------------------------------------
# sync point — one call per block boundary
# ---------------------------------------------------------------------------

def sync_point(params_start, params_end, sync_state: Dict[str, Any],
               cfg: SyncConfig, axis: str,
               param_axes=None) -> Tuple[Any, Dict[str, Any]]:
    """One model synchronization, inside shard_map with ``axis`` manual.

    ``params_start`` — the params the block started from (identical across
    replicas for ``overlap="none"``; per-replica under delayed/chunked);
    ``params_end`` — this replica's drifted params.
    ``param_axes`` — per-leaf logical axes (keeps the compressed-sync
    buffers sharded; see compression.allgather_mean_dequant).
    """
    if cfg.overlap == "delayed":
        return _sync_point_delayed(params_start, params_end, sync_state,
                                   cfg, axis, param_axes)
    if cfg.overlap == "chunked":
        return _sync_point_chunked(params_end, sync_state, cfg, axis,
                                   param_axes)

    delta = _f32_delta(params_end, params_start)
    new_state = dict(sync_state)
    mean_delta, new_ef = _exchange_mean(delta, sync_state.get("ef"), cfg,
                                        axis, param_axes)
    if new_ef is not None:
        new_state["ef"] = new_ef
    step_delta = _slowmo_step(mean_delta, sync_state, new_state, cfg)
    return _apply_f32(params_start, step_delta), new_state


def _sync_point_delayed(params_start, params_end, sync_state, cfg, axis,
                        param_axes):
    """Stale-by-one averaging: launch this block's mean, apply last block's.

    The returned params depend only on ``sync_state["pending"]`` (computed
    at the *previous* boundary), never on this boundary's collective — so in
    the compiled schedule the collective's first consumer is the *next*
    block's sync tail and XLA is free to run it under that block's compute.
    Replica k's params stay ``anchor + own latest local delta``; applying
    ``pending = mean_{i−1} − Δ_{i−1,k}`` swaps the stale local delta for its
    average, keeping divergence bounded by one block's drift.
    """
    delta = _f32_delta(params_end, params_start)
    new_state = dict(sync_state)
    mean_delta, new_ef = _exchange_mean(delta, sync_state.get("ef"), cfg,
                                        axis, param_axes)
    if new_ef is not None:
        new_state["ef"] = new_ef
    step_delta = _slowmo_step(mean_delta, sync_state, new_state, cfg)
    # apply the PREVIOUS boundary's correction to this block's end params
    new_params = _apply_f32(params_end, sync_state["pending"])
    new_state["pending"] = jax.tree.map(lambda m, d: m - d, step_delta, delta)
    return new_params, new_state


def chunk_assignment(leaves, chunks: int):
    """Leaf index → shard id, byte-balanced (greedy largest-first onto the
    lightest shard; ties broken by leaf order, so equal-size leaves land
    round-robin). Balancing by *bytes* rather than leaf count is what makes
    the cost model's per-sync ``/chunks`` wire accounting hold for skewed
    trees — a leaf-count round-robin would let one shard carry the whole
    embedding table. A single leaf larger than total/chunks still bounds
    the worst boundary from below (no intra-leaf splitting here)."""
    order = sorted(range(len(leaves)),
                   key=lambda i: (-int(np.prod(leaves[i].shape)), i))
    load = [0] * max(1, chunks)
    assign = [0] * len(leaves)
    for i in order:
        s = min(range(len(load)), key=lambda rr: (load[rr], rr))
        assign[i] = s
        load[s] += int(np.prod(leaves[i].shape))
    return assign


def _sync_point_chunked(params_end, sync_state, cfg, axis, param_axes):
    """Value-average one shard of the tree per boundary.

    ``params_start`` is irrelevant: a chunked leaf may not have synced for
    ``chunks`` blocks, so its replicas' block-start values already diverge —
    consistency is re-established from the *end* values (``mean_K(w)``).
    ``lax.switch`` keys the traced ``chunk_idx`` (replicated state, so every
    replica takes the same branch) into per-shard branches; only the taken
    branch's collective executes, so one boundary moves ~1/chunks of the
    tree's bytes (shards are byte-balanced — see chunk_assignment).
    """
    r = max(1, cfg.chunks)
    idx = sync_state["chunk_idx"]
    ef = sync_state.get("ef")
    have_ef = ef is not None
    ax_leaves = (jax.tree.leaves(
        param_axes, is_leaf=lambda x: x is None or isinstance(x, tuple))
        if param_axes is not None
        else [None] * len(jax.tree.leaves(params_end)))
    assign = chunk_assignment(jax.tree.leaves(params_end), r)

    def make_branch(rr):
        def branch(operands):
            p_end, ef_in = operands
            leaves, treedef = jax.tree.flatten(p_end)
            ef_leaves = (jax.tree.leaves(ef_in) if have_ef
                         else [None] * len(leaves))
            # shard-rr leaf subset as {leaf_index: value} dict pytrees
            sub = [i for i in range(len(leaves)) if assign[i] == rr]
            vals = {i: leaves[i].astype(jnp.float32) for i in sub}
            efs = {i: ef_leaves[i] for i in sub} if have_ef else None
            axs = {i: ax_leaves[i] for i in sub}
            mean, new_ef = _exchange_mean(vals, efs, cfg, axis, axs)
            new_leaves = list(leaves)
            new_ef_leaves = list(ef_leaves)
            for i in sub:
                new_leaves[i] = mean[i].astype(leaves[i].dtype)
                if have_ef and new_ef is not None:
                    new_ef_leaves[i] = new_ef[i]
            out_p = jax.tree.unflatten(treedef, new_leaves)
            out_ef = (jax.tree.unflatten(treedef, new_ef_leaves)
                      if have_ef else ef_in)
            return out_p, out_ef
        return branch

    operands = (params_end, ef)
    new_params, new_ef = jax.lax.switch(
        idx % r, [make_branch(rr) for rr in range(r)], operands)
    new_state = dict(sync_state)
    new_state["chunk_idx"] = idx + 1
    if have_ef:
        new_state["ef"] = new_ef
    return new_params, new_state


def flush_overlap(params, sync_state, cfg: SyncConfig, replica_dim: int = 0):
    """Collapse overlap staleness to the fully synchronized model.

    ``params``/``sync_state`` in the local-SGD stacked layout (leading
    replica dim). Under ``delayed`` each replica sits at ``anchor + ownΔ``
    with ``pending = stepΔ − ownΔ``, so ``params + pending`` is
    ``anchor + stepΔ`` on every replica — the model with every sync applied,
    *including* the slowmo momentum term inside stepΔ (a bare replica mean
    would drop it). ``chunked`` replicas differ only by not-yet-synced drift
    whose replica average is the consistent model. Call before
    checkpointing/evaluating a state trained with ``overlap != "none"``
    (see local_sgd.finalize_state). Returns the stacked layout with all
    replicas equal.
    """
    if cfg.overlap == "none":
        return params
    if cfg.overlap == "delayed":
        params = jax.tree.map(
            lambda p, q: (p.astype(jnp.float32) + q).astype(p.dtype),
            params, sync_state["pending"])

    def leaf(p):
        m = jnp.mean(p.astype(jnp.float32), axis=replica_dim, keepdims=True)
        return jnp.broadcast_to(m, p.shape).astype(p.dtype)
    return jax.tree.map(leaf, params)


# ---------------------------------------------------------------------------
# analytic byte accounting (delegates to the shared cost module)
# ---------------------------------------------------------------------------

def collective_bytes_per_sync(param_bytes: int, world: int,
                              cfg: SyncConfig) -> int:
    """Analytic wire bytes of one executed sync (napkin math / benchmarks).

    Single source of truth: :func:`repro.core.costmodel.wire_bytes_per_sync`
    (the MSF auto-tuner reads the same function).
    """
    return int(costmodel.wire_bytes_per_sync(param_bytes, world, cfg))


def amortized_bytes_per_step(param_bytes: int, world: int, cfg: SyncConfig) -> float:
    if cfg.strategy == "sync_every_step":
        return costmodel.wire_bytes_per_sync(param_bytes, world, cfg)
    return costmodel.wire_bytes_per_sync(param_bytes, world, cfg) / max(1, cfg.period)
