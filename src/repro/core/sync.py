"""Model-synchronization engine — the paper's contribution as a library.

The paper's finding: the *frequency* of model synchronization (MSF) is a
free knob — accuracy is flat across block sizes while communication cost
scales as ``1/H`` — so sync schedule should be a first-class config, not an
implementation detail. This module turns :class:`repro.config.SyncConfig`
into the sync-point transformation applied inside the compiled train block:

    sync_point(params_start, params_end, sync_state, cfg, axis)
        → (new_params, new_sync_state)

Strategy × overlap matrix (all reduce over the *replica* mesh axis):

=================  ==========================================================
``strategy``       when the sync point runs
=================  ==========================================================
sync_every_step    never (XLA's data-parallel grad all-reduce every step;
                   the engine is bypassed — config completeness only)
periodic           every H local steps (paper's DMS): ``w ← mean_K(w_local)``
hierarchical       as periodic, but the replica axis is the *pod* (DCN) axis
                   while the intra-pod data axis still syncs every step
=================  ==========================================================

=================  ==========================================================
``overlap``        what the sync point does when it runs
=================  ==========================================================
none               blocking: ``w ← w_start + mean_K(Δ)`` at the boundary —
                   the paper's semantics, bit-exact DMS ≡ SRDMS
delayed            stale-by-one: block *i* computes ``mean_K(Δᵢ)`` but the
                   result is applied at the end of block *i+1*; this block's
                   params depend only on the *previous* mean, so the
                   collective is free to run under block *i+1*'s compute.
                   Each replica's params stay ``anchor + own latest Δ``;
                   divergence is bounded by one block's local drift
                   (Stich 2018's local-SGD staleness regime)
chunked            partial: the parameter tree is split into ``cfg.chunks``
                   byte-balanced shards (equal-size leaves round-robin) and
                   one shard is value-averaged per block
                   (``w_leaf ← mean_K(w_leaf)``); each leaf syncs every
                   ``chunks·H`` steps and per-sync wire bytes shrink
                   ``chunks``×
=================  ==========================================================

=================  ==========================================================
``topology``       which replicas one sync couples (composes with overlap)
=================  ==========================================================
all                global collective (``pmean``/``psum``/all-gather): exact
                   consensus per sync, but one straggler stalls all K
ring               gossip: two ``lax.ppermute`` neighbor exchanges,
                   ``w ← (w + w_left + w_right)/3``. O(1) neighbor bytes
                   per sync (independent of K), no global barrier;
                   disagreement contracts by λ₂(ring, K) per round
pairwise           gossip: rotating disjoint odd–even pairs average with
                   weight ½ (round parity alternates the pairing so the
                   whole ring mixes). Even replica count required; one
                   partner's bytes per sync
=================  ==========================================================

Gossip sync points exchange parameter *values*, not deltas: mixing is a
doubly stochastic contraction, so per-replica anchors cannot drift apart
and the replica mean is invariant — ``flush_overlap``'s replica average is
the exact consensus target. ``overlap="delayed"`` composes by carrying the
gossip correction ``mix(w) − w`` one block stale (the ppermute feeds only
the carried state, never this block's compute); ``"chunked"`` gossips one
byte-balanced shard per boundary. Compression composes point-to-point: the
wire carries the quantized payload plus a per-sender scale (no shared-scale
``pmax``, and no psum headroom — the full int range is usable).

``gossip_async=True`` (gossip topologies only) makes the rounds
*unsynchronized*: each replica mixes with the **last received** neighbor
snapshot instead of the current-round one — a double-buffered ``ppermute``
exchange that sends this boundary's params and consumes the buffer the
previous boundary filled (bounded staleness = 1 round on the compiled
path). The stale correction ``(M w̃)_i − w̃_i`` still applies a doubly
stochastic M to one common snapshot ``w̃``, so the corrections sum to zero
across replicas and the replica mean stays invariant — the exact flush is
unchanged. This boundary's ppermute output feeds only the carried buffers,
never any compute before the *next* boundary, so the exchange has an
entire block of slack — overlap modes are rejected as redundant (they
would compound staleness past the 1-round bound).

Optional modifiers (beyond-paper, composable):

* ``compression="int8"`` — error-feedback int8 delta exchange
  (:mod:`repro.core.compression`), shrinking the sync collective 4×.
* ``compression="int16"`` — fixed-point 2-byte all-reduce wire.
* ``slowmo > 0`` — outer momentum on the averaged delta (SlowMo, Wang et
  al.); composes with ``overlap="delayed"`` (the momentum step is taken on
  the freshly averaged delta, applied one block late) and with
  ``"chunked"`` via a per-shard momentum: each leaf carries an ``anchor``
  (its value after its own last slowmo step) and momentum-steps on
  ``mean_K(w_leaf) − anchor`` at the boundaries where it syncs (see
  ``_sync_point_chunked``). Gossip topologies still reject slowmo — they
  never materialize a global mean.

Byte accounting lives in :mod:`repro.core.costmodel` (shared with the MSF
auto-tuner so the two can never drift).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import SyncConfig
from repro.core import compression as C
from repro.core import costmodel


def needs_replica_axis(cfg: SyncConfig) -> bool:
    return cfg.strategy in ("periodic", "hierarchical")


def validate(cfg: SyncConfig) -> None:
    if cfg.overlap not in ("none", "delayed", "chunked"):
        raise ValueError(f"unknown overlap mode: {cfg.overlap!r}")
    if cfg.topology not in ("all", "ring", "pairwise"):
        raise ValueError(f"unknown sync topology: {cfg.topology!r}")
    if cfg.topology != "all" and cfg.slowmo > 0.0:
        raise ValueError("slowmo steps on the globally averaged delta; "
                         "gossip topologies never materialize a global mean")
    if cfg.gossip_async:
        if cfg.topology == "all":
            raise ValueError(
                "gossip_async is the unsynchronized-round gossip mode; it "
                "needs topology='ring' or 'pairwise' (a global collective "
                "has no per-neighbor buffer to double-buffer)")
        if cfg.overlap != "none":
            raise ValueError(
                "gossip_async already runs the exchange a full block ahead "
                "of its consumer (bounded staleness = 1 round); "
                f"overlap={cfg.overlap!r} would compound the staleness — "
                "use overlap='none'")
    if cfg.overlap == "chunked" and cfg.chunks < 1:
        raise ValueError(f"chunks must be >= 1, got {cfg.chunks}")
    if cfg.adaptive:
        if cfg.adapt_every < 1:
            raise ValueError(
                f"adapt_every must be >= 1, got {cfg.adapt_every}")
        if cfg.adapt_hysteresis < 0.0:
            raise ValueError("adapt_hysteresis must be >= 0, "
                             f"got {cfg.adapt_hysteresis}")
        if cfg.adapt_rung_hysteresis < 1:
            raise ValueError("adapt_rung_hysteresis must be >= 1, "
                             f"got {cfg.adapt_rung_hysteresis}")
        if cfg.adapt_h_max < 1:
            raise ValueError(f"adapt_h_max must be >= 1, "
                             f"got {cfg.adapt_h_max}")
        if any(h < 1 for h in cfg.adapt_ladder):
            raise ValueError(f"adapt_ladder rungs must be >= 1, "
                             f"got {cfg.adapt_ladder}")


def init_sync_state(cfg: SyncConfig, params) -> Dict[str, Any]:
    validate(cfg)
    state: Dict[str, Any] = {}
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if cfg.compression in ("int8", "int16"):
        state["ef"] = C.init_error_feedback(params)
    if cfg.slowmo > 0.0:
        state["slowmo_m"] = zeros()
    if cfg.overlap == "delayed":
        # pending correction = (averaged step delta − own local delta) of the
        # previous block; applied to this block's end params (stale-by-one)
        state["pending"] = zeros()
    if cfg.overlap == "chunked":
        state["chunk_idx"] = jnp.zeros((), jnp.int32)
        if cfg.slowmo > 0.0:
            # per-shard outer momentum needs a per-leaf reference: the value
            # this leaf held right after ITS last slowmo step (leaves sync on
            # different boundaries, so a whole-tree block anchor can't exist)
            state["anchor"] = jax.tree.map(
                lambda p: p.astype(jnp.float32), params)
    if cfg.gossip_async:
        # double buffers of the unsynchronized-round exchange: ``sent`` is
        # the snapshot this replica transmitted at its previous boundary,
        # ``mixbuf`` the neighbor-weighted payload sum Σ_{j≠i} M_ij w̃_j it
        # received there (see init_async_buffers for the zero-correction
        # seed invariant).
        state["sent"], state["mixbuf"] = init_async_buffers(params,
                                                            cfg.topology)
    if cfg.topology == "pairwise" and cfg.overlap != "chunked":
        # round parity selects the odd/even pairing (chunked derives the
        # round from chunk_idx instead — one counter per concern)
        state["gossip_round"] = jnp.zeros((), jnp.int32)
    return state


def sync_state_axes(cfg: SyncConfig, param_axes) -> Dict[str, Any]:
    """Logical-axes tree matching init_sync_state (mirrors params)."""
    state: Dict[str, Any] = {}
    if cfg.compression in ("int8", "int16"):
        state["ef"] = param_axes
    if cfg.slowmo > 0.0:
        state["slowmo_m"] = param_axes
    if cfg.overlap == "delayed":
        state["pending"] = param_axes
    if cfg.overlap == "chunked":
        state["chunk_idx"] = ()
        if cfg.slowmo > 0.0:
            state["anchor"] = param_axes
    if cfg.gossip_async:
        state["sent"] = param_axes
        state["mixbuf"] = param_axes
    if cfg.topology == "pairwise" and cfg.overlap != "chunked":
        state["gossip_round"] = ()
    return state


# ---------------------------------------------------------------------------
# the mean-exchange primitive (shared by every overlap mode)
# ---------------------------------------------------------------------------

def _gossip_perms(k: int, topology: str):
    """Static ppermute (source → dest) lists, one list per wire exchange.

    ``ring`` returns both neighbor shifts; ``pairwise`` returns the two
    alternating pairings (even rounds pair (0,1)(2,3)…, odd rounds
    (1,2)(3,4)…(K−1,0)) — the caller selects by round parity.
    """
    if topology == "ring":
        return [[(i, (i + 1) % k) for i in range(k)],
                [(i, (i - 1) % k) for i in range(k)]]
    if topology == "pairwise":
        if k % 2:
            raise ValueError(
                f"topology='pairwise' needs an even replica count, got {k}")
        even = [(i, i ^ 1) for i in range(k)]
        odd = [(i, (i - 1) % k if i % 2 == 0 else (i + 1) % k)
               for i in range(k)]
        return [even, odd]
    raise ValueError(f"unknown gossip topology: {topology!r}")


def _mix_with(self_val, send, k: int, topology: str, round_idx):
    """Topology-weighted combine of own payload with the neighbors'.

    ``send(perm)`` returns the ``ppermute``'d payload for one wire
    exchange — the single definition of the gossip weighting (ring thirds,
    pairwise halves with parity-``cond`` pairing) shared by the raw-value
    and compressed paths.
    """
    if k == 1:
        return self_val
    perms = _gossip_perms(k, topology)
    if topology == "ring":
        return (self_val + send(perms[0]) + send(perms[1])) / 3.0
    if round_idx is None:
        # a frozen pairing would "converge" each disjoint pair to its own
        # mean and never reach global consensus — refuse rather than mix
        # wrongly (every engine path threads a counter: gossip_round, or
        # chunk_idx // chunks under chunked)
        raise ValueError("topology='pairwise' alternates its pairing by "
                         "round; pass round_idx")
    def pair(perm):
        return lambda v: (v + send(perm)) / 2.0
    return jax.lax.cond(round_idx % 2 == 0, pair(perms[0]), pair(perms[1]),
                        self_val)


def gossip_self_weight(topology: str) -> float:
    """Diagonal ``M_ii`` of the gossip mixing matrix (same for every i):
    ring thirds, pairwise halves. The async double buffer splits the mix
    into ``M_ii·own + Σ_{j≠i} M_ij·recv`` — this is the own-term weight."""
    if topology == "ring":
        return 1.0 / 3.0
    if topology == "pairwise":
        return 0.5
    raise ValueError(f"unknown gossip topology: {topology!r}")


def _recv_with(send, k: int, topology: str, round_idx):
    """Neighbor-weighted payload sum ``Σ_{j≠i} M_ij x_j`` — the receive
    half of one wire exchange (no self term). ``_mix_with`` ≡
    ``self_weight·own + _recv_with`` for the synchronous path; the async
    path banks this in ``mixbuf`` and consumes it one boundary later.
    """
    perms = _gossip_perms(k, topology)
    if topology == "ring":
        return (send(perms[0]) + send(perms[1])) / 3.0
    if round_idx is None:
        raise ValueError("topology='pairwise' alternates its pairing by "
                         "round; pass round_idx")
    def pair(perm):
        return lambda _: send(perm) / 2.0
    return jax.lax.cond(round_idx % 2 == 0, pair(perms[0]), pair(perms[1]),
                        0.0)


def gossip_mix(x, axis: str, topology: str, round_idx=None):
    """Mix one (uncompressed) array with its topology neighbors over
    ``axis`` — the doubly stochastic gossip step ``x ← Σ_j M_ij x_j``.

    Must run inside shard_map with ``axis`` manual. ``round_idx`` (traced
    i32) selects the pairwise round parity — required for ``pairwise``,
    ignored by ``ring``. The only collectives emitted are ``ppermute``s —
    no global barrier.
    """
    k = jax.lax.psum(1, axis)      # static at trace time
    return _mix_with(x, lambda perm: jax.lax.ppermute(x, axis, perm),
                     k, topology, round_idx)


def _gossip_exchange(values, ef, cfg: SyncConfig, axis: str, round_idx):
    """Neighbor-mixed pytree under ``cfg.topology``/``cfg.compression``.

    Returns ``(mixed_tree, new_ef_tree_or_None)`` like :func:`_exchange_mean`
    but moves only point-to-point ``ppermute`` payloads — no global
    collective. Compressed wires carry ``(q, per-sender scale)`` pairs and
    every replica mixes its *own dequantized* payload (not the raw value),
    so the mixing matrix stays doubly stochastic over what was actually
    transmitted; the quantization residual goes to error feedback.
    """
    k = jax.lax.psum(1, axis)      # static at trace time

    if cfg.compression in ("int8", "int16"):
        qmax, qdtype = ((127, jnp.int8) if cfg.compression == "int8"
                        else (32767, jnp.int16))

        def leaf(v, e):
            val = v.astype(jnp.float32) + e
            amax = jnp.max(jnp.abs(val))
            scale = jnp.maximum(amax, 1e-12) / qmax
            q = jnp.clip(jnp.round(val / scale), -qmax, qmax).astype(qdtype)
            deq_self = q.astype(jnp.float32) * scale

            def send(perm):
                qn = jax.lax.ppermute(q, axis, perm)
                sn = jax.lax.ppermute(scale, axis, perm)
                return qn.astype(jnp.float32) * sn

            return (_mix_with(deq_self, send, k, cfg.topology, round_idx),
                    val - deq_self)

        out = jax.tree.map(leaf, values, ef)
        is_t = lambda x: isinstance(x, tuple)
        mixed = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
        new_ef = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
        return mixed, new_ef

    def leaf(v):
        return gossip_mix(v.astype(jnp.float32), axis, cfg.topology,
                          round_idx)

    return jax.tree.map(leaf, values), None


def init_async_buffers(params, topology: str):
    """Seed ``(sent, mixbuf)`` for the async double buffers from a params
    pytree: as if every replica had transmitted its current model at a
    previous boundary, so when replicas start identical the first stale
    correction ``mixbuf + (M_ii−1)·sent`` is exactly zero. The single
    definition of the seed — init, resume (``local_sgd.finalize_state``)
    and the SVM carries all call it, so they cannot drift.
    """
    w_self = gossip_self_weight(topology)
    # at least f32 (bf16 params get f32 buffers) without downcasting an
    # f64 carry — lax.scan needs the carry dtype stable across boundaries
    sent = jax.tree.map(
        lambda p: p.astype(jnp.promote_types(p.dtype, jnp.float32)), params)
    mixbuf = jax.tree.map(lambda p: (1.0 - w_self) * p, sent)
    return sent, mixbuf


def gossip_recv(x, axis: str, topology: str, round_idx=None):
    """Receive half of one gossip exchange over ``axis``: the
    neighbor-weighted payload sum ``Σ_{j≠i} M_ij x_j`` (ppermutes only, no
    self term). ``gossip_mix(x) ≡ gossip_self_weight·x + gossip_recv(x)``;
    the async path banks this in its ``mixbuf`` double buffer instead of
    consuming it at the same boundary.
    """
    k = jax.lax.psum(1, axis)      # static at trace time
    return _recv_with(lambda perm: jax.lax.ppermute(x, axis, perm),
                      k, topology, round_idx)


def _gossip_async_exchange(values, ef, cfg: SyncConfig, axis: str,
                           round_idx):
    """Double-buffered half-exchange: ppermute this boundary's payload and
    return what lands in the buffers, to be *consumed at the next boundary*.

    Returns ``(recv_tree, sent_tree, new_ef_tree_or_None)``: ``recv`` is
    the neighbor-weighted payload sum ``Σ_{j≠i} M_ij p_j`` under this
    round's pairing and ``sent`` the own transmitted payload. Under
    compression the wire carries ``(q, per-sender scale)`` and ``sent`` is
    the own *dequantized* payload — every replica's stale mix then applies
    the doubly stochastic M to the same transmitted snapshot, and the
    quantization residual goes to error feedback.
    """
    k = jax.lax.psum(1, axis)      # static at trace time

    if cfg.compression in ("int8", "int16"):
        qmax, qdtype = ((127, jnp.int8) if cfg.compression == "int8"
                        else (32767, jnp.int16))

        def leaf(v, e):
            val = v + e
            amax = jnp.max(jnp.abs(val))
            scale = jnp.maximum(amax, 1e-12) / qmax
            q = jnp.clip(jnp.round(val / scale), -qmax, qmax).astype(qdtype)
            deq_self = q.astype(jnp.float32) * scale

            def send(perm):
                qn = jax.lax.ppermute(q, axis, perm)
                sn = jax.lax.ppermute(scale, axis, perm)
                return qn.astype(jnp.float32) * sn

            return (_recv_with(send, k, cfg.topology, round_idx),
                    deq_self, val - deq_self)

        out = jax.tree.map(leaf, values, ef)
        is_t = lambda x: isinstance(x, tuple)
        recv = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
        sent = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
        new_ef = jax.tree.map(lambda o: o[2], out, is_leaf=is_t)
        return recv, sent, new_ef

    def leaf(v):
        return _recv_with(lambda perm: jax.lax.ppermute(v, axis, perm),
                          k, cfg.topology, round_idx)

    return jax.tree.map(leaf, values), values, None


def _exchange_mean(values, ef, cfg: SyncConfig, axis: str, param_axes,
                   round_idx=None):
    """Replica exchange of a pytree over ``axis`` under cfg.compression.

    ``topology="all"`` returns the exact replica mean (global collective);
    gossip topologies return the neighbor-mixed values (``round_idx``
    selects the pairwise pairing). Returns ``(tree, new_ef_tree_or_None)``.
    ``values`` may be deltas (blocking/delayed under "all") or raw parameter
    values (chunked, and always under gossip); error feedback carries the
    quantization residual either way.
    """
    if cfg.topology != "all":
        return _gossip_exchange(values, ef, cfg, axis, round_idx)
    if cfg.compression == "int8":
        q, s, new_ef = C.compress_tree(values, ef)
        return C.allgather_mean_dequant(q, s, axis, param_axes), new_ef
    if cfg.compression == "int16":
        # fixed-point 2-byte wire via an ordinary (shape-preserving)
        # all-reduce: a psum of int16 composes cleanly with auto-axis
        # sharding, where the int8 all-gather materializes full leaves
        # per device and a bf16 pmean trips XLA's AllReducePromotion
        # CHECK (§Perf C-cell log). A shared per-tensor scale is agreed
        # via pmax first; ⌊log₂(32767/K)⌋ mantissa bits still beat bf16's
        # 8 at the same wire width for any realistic replica count.
        # Rounding error is carried in the EF buffer.
        k = jax.lax.psum(1, axis)          # static at trace time
        # headroom scales with the replica count so the int16 psum cannot
        # overflow: K·qmax ≤ 32767 (the old fixed ±8192 clip wrapped at
        # world ≥ 4 — 4·8192 = 32768 > int16 max)
        qmax = 32767 // k

        def int16_leaf(d, e):
            v = d + e
            amax = jax.lax.pmax(jnp.max(jnp.abs(v)), axis)
            scale = jnp.maximum(amax, 1e-12) / qmax
            q = jnp.clip(jnp.round(v / scale), -qmax, qmax
                         ).astype(jnp.int16)
            summed = jax.lax.psum(q, axis).astype(jnp.float32)
            mean = summed * scale / k
            return mean, v - q.astype(jnp.float32) * scale
        out = jax.tree.map(int16_leaf, values, ef)
        is_t = lambda x: isinstance(x, tuple)
        mean = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
        new_ef = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
        return mean, new_ef
    return jax.tree.map(lambda d: jax.lax.pmean(d, axis), values), None


def _slowmo_step(mean_delta, sync_state, new_state, cfg: SyncConfig):
    """Outer momentum on the averaged delta; returns the applied delta."""
    if cfg.slowmo <= 0.0:
        return mean_delta
    m = jax.tree.map(lambda mm, d: cfg.slowmo * mm + d,
                     sync_state["slowmo_m"], mean_delta)
    new_state["slowmo_m"] = m
    return jax.tree.map(lambda mm: cfg.slowmo_lr * mm, m)


def _f32_delta(params_end, params_start):
    return jax.tree.map(
        lambda e, s: e.astype(jnp.float32) - s.astype(jnp.float32),
        params_end, params_start)


def _apply_f32(params, delta):
    return jax.tree.map(
        lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype),
        params, delta)


# ---------------------------------------------------------------------------
# sync point — one call per block boundary
# ---------------------------------------------------------------------------

def sync_point(params_start, params_end, sync_state: Dict[str, Any],
               cfg: SyncConfig, axis: str,
               param_axes=None) -> Tuple[Any, Dict[str, Any]]:
    """One model synchronization, inside shard_map with ``axis`` manual.

    ``params_start`` — the params the block started from (identical across
    replicas for ``overlap="none"``; per-replica under delayed/chunked and
    any gossip topology); ``params_end`` — this replica's drifted params.
    ``param_axes`` — per-leaf logical axes (keeps the compressed-sync
    buffers sharded; see compression.allgather_mean_dequant).
    """
    if cfg.gossip_async:
        return _sync_point_gossip_async(params_end, sync_state, cfg, axis)
    if cfg.topology != "all" and cfg.overlap != "chunked":
        return _sync_point_gossip(params_end, sync_state, cfg, axis)
    if cfg.overlap == "delayed":
        return _sync_point_delayed(params_start, params_end, sync_state,
                                   cfg, axis, param_axes)
    if cfg.overlap == "chunked":
        return _sync_point_chunked(params_end, sync_state, cfg, axis,
                                   param_axes)

    delta = _f32_delta(params_end, params_start)
    new_state = dict(sync_state)
    mean_delta, new_ef = _exchange_mean(delta, sync_state.get("ef"), cfg,
                                        axis, param_axes)
    if new_ef is not None:
        new_state["ef"] = new_ef
    step_delta = _slowmo_step(mean_delta, sync_state, new_state, cfg)
    return _apply_f32(params_start, step_delta), new_state


def _sync_point_delayed(params_start, params_end, sync_state, cfg, axis,
                        param_axes):
    """Stale-by-one averaging: launch this block's mean, apply last block's.

    The returned params depend only on ``sync_state["pending"]`` (computed
    at the *previous* boundary), never on this boundary's collective — so in
    the compiled schedule the collective's first consumer is the *next*
    block's sync tail and XLA is free to run it under that block's compute.
    Replica k's params stay ``anchor + own latest local delta``; applying
    ``pending = mean_{i−1} − Δ_{i−1,k}`` swaps the stale local delta for its
    average, keeping divergence bounded by one block's drift.
    """
    delta = _f32_delta(params_end, params_start)
    new_state = dict(sync_state)
    mean_delta, new_ef = _exchange_mean(delta, sync_state.get("ef"), cfg,
                                        axis, param_axes)
    if new_ef is not None:
        new_state["ef"] = new_ef
    step_delta = _slowmo_step(mean_delta, sync_state, new_state, cfg)
    # apply the PREVIOUS boundary's correction to this block's end params
    new_params = _apply_f32(params_end, sync_state["pending"])
    new_state["pending"] = jax.tree.map(lambda m, d: m - d, step_delta, delta)
    return new_params, new_state


def _sync_point_gossip(params_end, sync_state, cfg, axis):
    """Gossip sync (ring/pairwise): mix parameter *values* with neighbors.

    Value form (``w ← Σ_j M_ij w_j``, not a delta exchange) because gossip
    never re-establishes a common anchor: a delta-only exchange would let
    the per-replica anchors drift apart unboundedly, while value mixing
    contracts the whole disagreement by λ₂ per round and keeps the replica
    mean invariant (M is doubly stochastic).

    ``overlap="none"`` applies the mixed values at this boundary (blocking
    on two ppermutes — still no global barrier). ``overlap="delayed"``
    carries the gossip correction ``mix(w) − w`` one block stale: this
    boundary's ppermute output feeds only ``pending``, so the exchange is
    free to run under the next block's compute.
    """
    new_state = dict(sync_state)
    rnd = sync_state.get("gossip_round")
    if rnd is not None:
        new_state["gossip_round"] = rnd + 1
    vals = jax.tree.map(lambda p: p.astype(jnp.float32), params_end)
    mixed, new_ef = _gossip_exchange(vals, sync_state.get("ef"), cfg, axis,
                                     rnd)
    if new_ef is not None:
        new_state["ef"] = new_ef
    if cfg.overlap == "delayed":
        new_params = _apply_f32(params_end, sync_state["pending"])
        new_state["pending"] = jax.tree.map(lambda m, v: m - v, mixed, vals)
        return new_params, new_state
    new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), mixed,
                              params_end)
    return new_params, new_state


def _sync_point_gossip_async(params_end, sync_state, cfg, axis):
    """Asynchronous (unsynchronized-round) gossip: mix with the *last
    received* neighbor snapshot instead of the current-round one.

    The correction applied at this boundary is ``(M w̃)_i − w̃_i`` where
    ``w̃`` is the snapshot every replica transmitted at its PREVIOUS
    boundary — reconstructed from the double buffers as
    ``mixbuf + M_ii·sent − sent``. M is doubly stochastic and applies to
    one common snapshot, so the corrections sum to zero over replicas and
    the replica mean stays invariant (exact flush unchanged). This
    boundary then transmits the *post-correction* params: with zero local
    drift the recurrence collapses to synchronous gossip one round behind
    (``w_t = M w_{t−1}``), so the per-round contraction is still λ₂ — what
    staleness costs is one extra block of unmixed drift, which the
    auto-tuner charges via ``costmodel.effective_spectral_gap``.

    Schedule-wise this is stronger than ``overlap="delayed"``: the
    ppermute output feeds only the carried buffers, and nothing before the
    *next* boundary reads them — the exchange has an entire block of slack
    and a replica never waits for a neighbor's current round.
    """
    new_state = dict(sync_state)
    rnd = sync_state.get("gossip_round")
    if rnd is not None:
        new_state["gossip_round"] = rnd + 1
    w_self = gossip_self_weight(cfg.topology)
    vals = jax.tree.map(lambda p: p.astype(jnp.float32), params_end)
    new_w = jax.tree.map(
        lambda v, rb, s: v + rb + (w_self - 1.0) * s,
        vals, sync_state["mixbuf"], sync_state["sent"])
    recv, sent, new_ef = _gossip_async_exchange(
        new_w, sync_state.get("ef"), cfg, axis, rnd)
    new_state["mixbuf"] = recv
    new_state["sent"] = sent
    if new_ef is not None:
        new_state["ef"] = new_ef
    new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), new_w,
                              params_end)
    return new_params, new_state


def chunk_assignment(leaves, chunks: int):
    """Leaf index → shard id, byte-balanced (greedy largest-first onto the
    lightest shard; ties broken by leaf order, so equal-size leaves land
    round-robin). Balancing by *bytes* — ``size · dtype.itemsize``, not
    element count, so mixed-precision trees (bf16 params + fp32 buffers)
    balance by what actually crosses the wire — is what makes the cost
    model's per-sync ``/chunks`` accounting hold for skewed trees; a
    leaf-count round-robin would let one shard carry the whole embedding
    table. A single leaf larger than total/chunks still bounds the worst
    boundary from below (no intra-leaf splitting here)."""
    def nbytes(leaf):
        return int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    order = sorted(range(len(leaves)),
                   key=lambda i: (-nbytes(leaves[i]), i))
    load = [0] * max(1, chunks)
    assign = [0] * len(leaves)
    for i in order:
        s = min(range(len(load)), key=lambda rr: (load[rr], rr))
        assign[i] = s
        load[s] += nbytes(leaves[i])
    return assign


def _sync_point_chunked(params_end, sync_state, cfg, axis, param_axes):
    """Value-average one shard of the tree per boundary.

    ``params_start`` is irrelevant: a chunked leaf may not have synced for
    ``chunks`` blocks, so its replicas' block-start values already diverge —
    consistency is re-established from the *end* values (``mean_K(w)``).
    ``lax.switch`` keys the traced ``chunk_idx`` (replicated state, so every
    replica takes the same branch) into per-shard branches; only the taken
    branch's collective executes, so one boundary moves ~1/chunks of the
    tree's bytes (shards are byte-balanced — see chunk_assignment). Under a
    gossip topology the shard is neighbor-mixed instead of globally
    averaged; the pairwise round parity advances once per full round-robin
    pass (``chunk_idx // chunks``) so each leaf alternates pairings across
    its own syncs.

    ``slowmo > 0`` composes via a PER-SHARD outer momentum: each leaf keeps
    an ``anchor`` (its value right after its own last slowmo step) and a
    momentum buffer, and this boundary's synced leaves step

        m ← β·m + (mean_K(w_leaf) − anchor);  w_leaf ← anchor + lr_out·m

    with the anchor advanced to the new value. Leaves sync on different
    boundaries, so a whole-tree block delta never exists — the per-leaf
    anchor supplies the reference the blocking/delayed paths get from
    ``params_start``. For ``chunks=1`` (anchor ≡ block start, mean of ends
    ≡ start + meanΔ) this reduces exactly to the blocking slowmo step.
    """
    r = max(1, cfg.chunks)
    idx = sync_state["chunk_idx"]
    ef = sync_state.get("ef")
    have_ef = ef is not None
    slowmo = cfg.slowmo > 0.0
    mom = sync_state.get("slowmo_m") if slowmo else None
    anchor = sync_state.get("anchor") if slowmo else None
    ax_leaves = (jax.tree.leaves(
        param_axes, is_leaf=lambda x: x is None or isinstance(x, tuple))
        if param_axes is not None
        else [None] * len(jax.tree.leaves(params_end)))
    assign = chunk_assignment(jax.tree.leaves(params_end), r)

    def make_branch(rr):
        def branch(operands):
            p_end, ef_in, m_in, a_in = operands
            leaves, treedef = jax.tree.flatten(p_end)
            ef_leaves = (jax.tree.leaves(ef_in) if have_ef
                         else [None] * len(leaves))
            m_leaves = jax.tree.leaves(m_in) if slowmo else None
            a_leaves = jax.tree.leaves(a_in) if slowmo else None
            # shard-rr leaf subset as {leaf_index: value} dict pytrees
            sub = [i for i in range(len(leaves)) if assign[i] == rr]
            vals = {i: leaves[i].astype(jnp.float32) for i in sub}
            efs = {i: ef_leaves[i] for i in sub} if have_ef else None
            axs = {i: ax_leaves[i] for i in sub}
            mean, new_ef = _exchange_mean(vals, efs, cfg, axis, axs,
                                          round_idx=idx // r)
            new_leaves = list(leaves)
            new_ef_leaves = list(ef_leaves)
            new_m = list(m_leaves) if slowmo else None
            new_a = list(a_leaves) if slowmo else None
            for i in sub:
                if slowmo:
                    m = cfg.slowmo * m_leaves[i] + (mean[i] - a_leaves[i])
                    w_new = a_leaves[i] + cfg.slowmo_lr * m
                    new_m[i] = m
                    new_a[i] = w_new
                    new_leaves[i] = w_new.astype(leaves[i].dtype)
                else:
                    new_leaves[i] = mean[i].astype(leaves[i].dtype)
                if have_ef and new_ef is not None:
                    new_ef_leaves[i] = new_ef[i]
            out_p = jax.tree.unflatten(treedef, new_leaves)
            out_ef = (jax.tree.unflatten(treedef, new_ef_leaves)
                      if have_ef else ef_in)
            out_m = jax.tree.unflatten(treedef, new_m) if slowmo else m_in
            out_a = jax.tree.unflatten(treedef, new_a) if slowmo else a_in
            return out_p, out_ef, out_m, out_a
        return branch

    operands = (params_end, ef, mom, anchor)
    new_params, new_ef, new_m, new_anchor = jax.lax.switch(
        idx % r, [make_branch(rr) for rr in range(r)], operands)
    new_state = dict(sync_state)
    new_state["chunk_idx"] = idx + 1
    if have_ef:
        new_state["ef"] = new_ef
    if slowmo:
        new_state["slowmo_m"] = new_m
        new_state["anchor"] = new_anchor
    return new_params, new_state


def flush_overlap(params, sync_state, cfg: SyncConfig, replica_dim: int = 0):
    """Collapse overlap staleness to the fully synchronized model.

    ``params``/``sync_state`` in the local-SGD stacked layout (leading
    replica dim). Under ``delayed`` each replica sits at ``anchor + ownΔ``
    with ``pending = stepΔ − ownΔ``, so ``params + pending`` is
    ``anchor + stepΔ`` on every replica — the model with every sync applied,
    *including* the slowmo momentum term inside stepΔ (a bare replica mean
    would drop it). ``chunked`` replicas differ only by not-yet-synced drift
    whose replica average is the consistent model; gossip topologies leave
    replicas within the geometric consensus envelope whose replica average
    is the invariant mean (doubly stochastic mixing); under
    ``gossip_async`` the in-flight buffer corrections sum to zero across
    replicas, so the bare replica mean is already the consensus target
    (``finalize_state`` re-seeds the double buffers from the flushed
    params so resume starts with a zero stale correction). When ``compression``
    is on, the error-feedback residual — quantization error each replica
    would have re-submitted at its next sync, where averaging would have
    spread its replica mean to everyone — is folded in before the collapse,
    so a checkpoint-resume from the flushed state neither loses nor
    double-counts the carried error (``finalize_state`` zeroes the EF
    buffer to match). Call before checkpointing/evaluating a state trained
    with ``overlap != "none"`` or ``topology != "all"`` (see
    local_sgd.finalize_state). Returns the stacked layout with all replicas
    equal.
    """
    if cfg.overlap == "none" and cfg.topology == "all":
        return params
    if cfg.overlap == "delayed":
        params = jax.tree.map(
            lambda p, q: (p.astype(jnp.float32) + q).astype(p.dtype),
            params, sync_state["pending"])
    if "ef" in sync_state:
        params = jax.tree.map(
            lambda p, e: (p.astype(jnp.float32) + e).astype(p.dtype),
            params, sync_state["ef"])

    def leaf(p):
        m = jnp.mean(p.astype(jnp.float32), axis=replica_dim, keepdims=True)
        return jnp.broadcast_to(m, p.shape).astype(p.dtype)
    return jax.tree.map(leaf, params)


# ---------------------------------------------------------------------------
# analytic byte accounting (delegates to the shared cost module)
# ---------------------------------------------------------------------------

def collective_bytes_per_sync(param_bytes: int, world: int,
                              cfg: SyncConfig) -> int:
    """Analytic wire bytes of one executed sync (napkin math / benchmarks).

    Single source of truth: :func:`repro.core.costmodel.wire_bytes_per_sync`
    (the MSF auto-tuner reads the same function).
    """
    return int(costmodel.wire_bytes_per_sync(param_bytes, world, cfg))


def amortized_bytes_per_step(param_bytes: int, world: int, cfg: SyncConfig) -> float:
    if cfg.strategy == "sync_every_step":
        return costmodel.wire_bytes_per_sync(param_bytes, world, cfg)
    return costmodel.wire_bytes_per_sync(param_bytes, world, cfg) / max(1, cfg.period)
