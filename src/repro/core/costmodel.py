"""Single source of truth for sync-collective cost accounting.

Both :func:`repro.core.sync.collective_bytes_per_sync` (napkin math and
benchmark labels) and :func:`repro.core.autotune.sync_time_s` (the MSF
auto-tuner) derive from :func:`wire_bytes_per_sync`; before this module the
two sites duplicated the formulas and could drift.

Accounting conventions (per chip, ``param_bytes`` is the fp32 footprint of
the synced tree on this chip):

* fp32 ring all-reduce moves ``2·P·(K−1)/K`` bytes.
* int8 exchange is an all-gather (summing int8 on the wire would overflow):
  ``P/4·(K−1)`` bytes.
* int16 fixed-point all-reduce: ``P/2`` payload through the ring,
  ``2·(P/2)·(K−1)/K = P·(K−1)/K`` bytes.

Overlap modes (``SyncConfig.overlap``):

* ``delayed`` moves the same bytes — it hides them behind the next block's
  compute instead of shrinking them, so the *bytes* are unchanged and only
  the *time* model (:func:`overlapped_step_time`) differs.
* ``chunked`` syncs one of ``cfg.chunks`` round-robin shards per sync point,
  dividing per-sync wire bytes by the shard count.
"""
from __future__ import annotations

from repro.config.base import SyncConfig


def wire_bytes_per_sync(param_bytes: int, world: int, cfg: SyncConfig) -> float:
    """Wire bytes of ONE executed sync collective (per chip)."""
    if cfg.compression == "int8":
        wire = param_bytes / 4 * (world - 1)
    elif cfg.compression == "int16":
        wire = param_bytes * (world - 1) / world
    else:
        wire = 2 * param_bytes * (world - 1) / world
    if cfg.overlap == "chunked":
        wire /= max(1, cfg.chunks)
    return wire


def overlapped_step_time(step_time_s: float, sync_time_s: float, h: int,
                         cfg: SyncConfig) -> float:
    """Per-optimizer-step wall clock under the configured overlap mode.

    * blocking (``none``/``chunked``): ``T_step + T_sync/H`` — the collective
      sits on the critical path at every block boundary (chunked has already
      shrunk ``T_sync`` by the shard count via the wire-bytes model).
    * ``delayed``: ``max(T_step·H, T_sync)/H`` — the collective runs
      concurrently with the next block's H steps of compute and is exposed
      only when it outlasts them.
    """
    h = max(1, h)
    if cfg.overlap == "delayed":
        return max(step_time_s * h, sync_time_s) / h
    return step_time_s + sync_time_s / h
