"""Single source of truth for sync-collective cost accounting.

Both :func:`repro.core.sync.collective_bytes_per_sync` (napkin math and
benchmark labels) and :func:`repro.core.autotune.sync_time_s` (the MSF
auto-tuner) derive from :func:`wire_bytes_per_sync`; before this module the
two sites duplicated the formulas and could drift.

Accounting conventions (per chip, ``param_bytes`` is the fp32 footprint of
the synced tree on this chip):

* fp32 ring all-reduce moves ``2·P·(K−1)/K`` bytes.
* int8 exchange is an all-gather (summing int8 on the wire would overflow):
  ``P/4·(K−1)`` bytes.
* int16 fixed-point all-reduce: ``P/2`` payload through the ring,
  ``2·(P/2)·(K−1)/K = P·(K−1)/K`` bytes.

Overlap modes (``SyncConfig.overlap``):

* ``delayed`` moves the same bytes — it hides them behind the next block's
  compute instead of shrinking them, so the *bytes* are unchanged and only
  the *time* model (:func:`overlapped_step_time`) differs.
* ``chunked`` syncs one of ``cfg.chunks`` round-robin shards per sync point,
  dividing per-sync wire bytes by the shard count.

Topologies (``SyncConfig.topology``):

* ``all`` — the global collective above; wire bytes grow with ``(K−1)/K``
  (fp32/int16 ring all-reduce) or ``K−1`` (int8 all-gather).
* ``ring`` — each chip sends its payload to exactly two ``ppermute``
  neighbors: ``2·payload`` bytes per sync, **independent of K**. The point-
  to-point wire carries the compressed payload directly (fp32 ``P``, int16
  ``P/2``, int8 ``P/4``), with a per-sender scale instead of the all-reduce's
  shared one.
* ``pairwise`` — one rotating partner per sync: ``1·payload`` bytes.

Gossip pays for the byte saving in *mixing speed*: one round contracts the
replica disagreement by only λ₂ (the mixing matrix's second-largest
eigenvalue modulus, :func:`gossip_lambda2`) instead of collapsing it to
zero. The auto-tuner converts the spectral gap ``1 − λ₂`` into a tighter H
cap (:func:`repro.core.autotune.choose_period`).
"""
from __future__ import annotations

import functools

import numpy as np

from repro.config.base import SyncConfig


def _payload_factor(compression: str) -> float:
    """Wire bytes per fp32 parameter byte for the compressed payload."""
    if compression == "int8":
        return 0.25
    if compression == "int16":
        return 0.5
    return 1.0


def gossip_degree(topology: str) -> int:
    """Neighbors a replica SENDS to per sync round (0 = global collective)."""
    if topology == "ring":
        return 2
    if topology == "pairwise":
        return 1
    return 0


def wire_bytes_per_sync(param_bytes: int, world: int, cfg: SyncConfig) -> float:
    """Wire bytes of ONE executed sync collective (per chip)."""
    if cfg.topology in ("ring", "pairwise"):
        # point-to-point neighbor exchange: degree × compressed payload,
        # independent of the replica count (no global barrier, no ring pass)
        wire = gossip_degree(cfg.topology) * param_bytes * _payload_factor(
            cfg.compression)
    elif cfg.compression == "int8":
        wire = param_bytes / 4 * (world - 1)
    elif cfg.compression == "int16":
        wire = param_bytes * (world - 1) / world
    else:
        wire = 2 * param_bytes * (world - 1) / world
    if cfg.overlap == "chunked":
        wire /= max(1, cfg.chunks)
    return wire


# ---------------------------------------------------------------------------
# gossip mixing matrices and their spectra (shared with the sync engine's
# vmap simulation and the auto-tuner's convergence guardrail)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def mixing_matrices(world: int, topology: str):
    """Per-round doubly stochastic mixing matrices as a tuple of (K, K)
    ``np.float64`` arrays; round r applies matrix ``r % len(out)``.

    * ``all``      → one matrix, ``1/K`` everywhere (exact consensus).
    * ``ring``     → one circulant: 1/3 on the diagonal and both off-ring
                     diagonals (for K=2 the single neighbor arrives twice,
                     giving [[1/3, 2/3], [2/3, 1/3]] — still doubly
                     stochastic).
    * ``pairwise`` → two alternating odd–even pairings: even rounds average
                     pairs (0,1)(2,3)…, odd rounds (1,2)(3,4)…(K−1,0).
                     Requires even K so every replica has a partner.
    """
    if topology == "all":
        return (np.full((world, world), 1.0 / world),)
    if topology == "ring":
        m = np.zeros((world, world))
        for i in range(world):
            m[i, i] += 1.0 / 3.0
            m[i, (i + 1) % world] += 1.0 / 3.0
            m[i, (i - 1) % world] += 1.0 / 3.0
        return (m,)
    if topology == "pairwise":
        if world % 2:
            raise ValueError(
                f"topology='pairwise' needs an even replica count, got {world}")
        mats = []
        for parity in (0, 1):
            m = np.zeros((world, world))
            for i in range(world):
                if parity == 0:
                    j = i ^ 1
                else:
                    j = (i - 1) % world if i % 2 == 0 else (i + 1) % world
                m[i, i] = m[i, j] = 0.5
            mats.append(m)
        return tuple(mats)
    raise ValueError(f"unknown topology: {topology!r}")


@functools.lru_cache(maxsize=None)
def gossip_lambda2(world: int, topology: str) -> float:
    """Per-round disagreement contraction factor λ₂ ∈ [0, 1).

    Second-largest eigenvalue modulus of the round-averaged mixing operator:
    one gossip round shrinks ``‖w_k − mean(w)‖`` by at most λ₂. For the
    alternating pairwise schedule λ₂ is the geometric per-round mean over
    the two-round product (a single pairwise round alone does not contract
    the worst-case disagreement). ``all`` → 0 (exact consensus per round).
    """
    if world <= 1 or topology == "all":
        return 0.0
    mats = mixing_matrices(world, topology)
    prod = functools.reduce(np.matmul, reversed(mats))
    eig = np.sort(np.abs(np.linalg.eigvals(prod)))[::-1]
    lam = float(eig[1]) if len(eig) > 1 else 0.0
    return min(1.0, max(0.0, lam ** (1.0 / len(mats))))


def spectral_gap(world: int, topology: str) -> float:
    """``1 − λ₂``: the per-round consensus gain of the topology."""
    return 1.0 - gossip_lambda2(world, topology)


def effective_spectral_gap(world: int, topology: str, *,
                           staleness: int = 0) -> float:
    """Staleness-aware consensus gain of a gossip round.

    Async (unsynchronized-round) gossip mixes snapshots that are
    ``staleness`` rounds old. The drift-free contraction *rate* is
    unchanged — with zero local drift the double-buffered recurrence
    collapses to synchronous gossip ``staleness`` rounds behind
    (``w_t = M w_{t−1}``, tested in test_async_gossip) — but each block's
    local drift now waits ``staleness`` extra rounds before its first
    mixing, so the unmixed-drift window grows from ``H/gap`` steps to
    ``(1+staleness)·H/gap``. The drift guardrail scales its cap by the
    gap, so charging staleness as ``gap/(1+s)`` makes the effective
    averaging period — and therefore the H cap — account for the stale
    round exactly. ``staleness=0`` is the synchronous gossip gap.
    """
    if staleness < 0:
        raise ValueError(f"staleness must be >= 0, got {staleness}")
    return spectral_gap(world, topology) / (1.0 + staleness)


def overlapped_step_time(step_time_s: float, sync_time_s: float, h: int,
                         cfg: SyncConfig) -> float:
    """Per-optimizer-step wall clock under the configured overlap mode.

    * blocking (``none``/``chunked``): ``T_step + T_sync/H`` — the collective
      sits on the critical path at every block boundary (chunked has already
      shrunk ``T_sync`` by the shard count via the wire-bytes model).
    * ``delayed`` — and ``gossip_async``, whose double-buffered exchange is
      a full block ahead of its consumer by construction:
      ``max(T_step·H, T_sync)/H`` — the collective runs concurrently with
      the next block's H steps of compute and is exposed only when it
      outlasts them.
    """
    h = max(1, h)
    if cfg.overlap == "delayed" or cfg.gossip_async:
        return max(step_time_s * h, sync_time_s) / h
    return step_time_s + sync_time_s / h
