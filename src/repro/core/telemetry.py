"""Lightweight host-side timing telemetry for the sync schedule.

The MSF auto-tuner (:mod:`repro.core.autotune`) needs two numbers per
(model × mesh × fabric): ``T_step`` (compute time per optimizer step) and
``T_sync`` (one executed sync collective). This module collects both from
the *running* trainer — jitted code cannot time itself, so the timers wrap
the host-side step invocations (``jax.block_until_ready`` boundaries):

* the SVM timed-step path (``svm.dms_timed_steps``) measures compute and
  sync separately → :meth:`BlockTelemetry.record_step_time` /
  :meth:`record_sync_time` feed the EMAs directly;
* the LM block path (``local_sgd.make_train_step``) only sees whole-block
  wall times ``T(H) = H·T_step + T_sync`` → :meth:`record_block` keeps a
  per-H EMA and, once two distinct H's have been observed (the adaptive
  controller's H moves provide them), solves the two-parameter model by
  least squares on ``y = T_step + T_sync·(1/H)``.

The first sample of each kind is dropped (``warmup``) so jit compilation
never poisons the EMAs. All state is plain Python floats — safe to read
from the training loop at any block boundary.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple


class EMA:
    """Exponential moving average; ``None`` until the first update."""

    def __init__(self, decay: float = 0.8):
        self.decay = decay
        self.value: Optional[float] = None

    def update(self, x: float) -> float:
        self.value = (x if self.value is None
                      else self.decay * self.value + (1 - self.decay) * x)
        return self.value


class BlockTelemetry:
    """Measured ``T_step`` / ``T_sync`` estimates from the timed paths."""

    def __init__(self, decay: float = 0.8, warmup: int = 1):
        self._decay = decay
        self._step = EMA(decay)
        self._sync = EMA(decay)
        self._skip_step = warmup
        self._skip_sync = warmup
        self._skip_block = warmup
        self._block_by_h: Dict[int, EMA] = {}   # H → per-STEP wall-time EMA
        self._block_n_by_h: Dict[int, int] = {}  # H → recorded block count
        self.n_steps = 0
        self.n_syncs = 0
        self.n_blocks = 0

    # ------------------------------------------------------------ direct
    def record_step_time(self, seconds: float, steps: int = 1) -> None:
        """Measured compute-only time of ``steps`` optimizer steps."""
        if self._skip_step > 0:
            self._skip_step -= 1
            return
        self._step.update(seconds / max(1, steps))
        self.n_steps += steps

    def record_sync_time(self, seconds: float) -> None:
        """Measured time of one executed sync collective."""
        if self._skip_sync > 0:
            self._skip_sync -= 1
            return
        self._sync.update(seconds)
        self.n_syncs += 1

    # ----------------------------------------------------------- blocks
    def record_block(self, h: int, block_s: float,
                     sync_s: Optional[float] = None) -> None:
        """One whole sync block (H steps + boundary sync) of wall time.

        With a separately measured ``sync_s`` the split is exact; without
        it the (H, per-step time) pair feeds the least-squares separation.
        """
        if self._skip_block > 0:
            self._skip_block -= 1
            return
        self.n_blocks += 1
        h = max(1, int(h))
        self._block_n_by_h[h] = self._block_n_by_h.get(h, 0) + 1
        if sync_s is not None:
            self._sync.update(sync_s)
            self.n_syncs += 1
            self._step.update(max(block_s - sync_s, 0.0) / h)
            self.n_steps += h
            return
        self._block_by_h.setdefault(h, EMA(self._decay)).update(block_s / h)

    def _solve_blocks(self) -> Optional[Tuple[float, float]]:
        """Least squares of ``y = T_step + T_sync·x`` over x = 1/H."""
        pts = [(1.0 / h, e.value) for h, e in self._block_by_h.items()
               if e.value is not None]
        if len(pts) < 2:
            return None
        n = len(pts)
        sx = sum(x for x, _ in pts)
        sy = sum(y for _, y in pts)
        sxx = sum(x * x for x, _ in pts)
        sxy = sum(x * y for x, y in pts)
        den = n * sxx - sx * sx
        if abs(den) < 1e-18:
            return None
        t_sync = (n * sxy - sx * sy) / den
        t_step = (sy - t_sync * sx) / n
        return max(t_step, 0.0), max(t_sync, 0.0)

    # ---------------------------------------------------------- reading
    def estimates(self) -> Optional[Tuple[float, float]]:
        """(T_step, T_sync) in seconds, or None until enough data."""
        if self._step.value is not None and self._sync.value is not None:
            return self._step.value, self._sync.value
        return self._solve_blocks()

    def per_step_s(self) -> Optional[float]:
        """Crude per-step wall time when the split is underdetermined:
        the direct T_step EMA if one exists, else the mean of the per-H
        block EMAs (sync amortized in — an upper bound on T_step)."""
        if self._step.value is not None:
            return self._step.value
        vals = [e.value for e in self._block_by_h.values()
                if e.value is not None]
        return sum(vals) / len(vals) if vals else None

    def per_rung(self) -> Dict[int, dict]:
        """Per-H block stats — the H-ladder runtime's rung telemetry.

        ``per_step_s`` is the rung's whole-block wall time divided by H
        (sync amortized in); ``blocks`` how many blocks ran at that rung.
        Rungs observed only through the direct (separately timed) path
        report counts without a per-step EMA.
        """
        out: Dict[int, dict] = {}
        for h in sorted(self._block_n_by_h):
            ema = self._block_by_h.get(h)
            out[h] = {
                "per_step_s": ema.value if ema is not None else None,
                "blocks": self._block_n_by_h[h],
            }
        return out

    def to_dict(self) -> dict:
        est = self.estimates()
        return {
            "t_step_s": est[0] if est else None,
            "t_sync_s": est[1] if est else None,
            "n_steps": self.n_steps,
            "n_syncs": self.n_syncs,
            "n_blocks": self.n_blocks,
            "per_rung": {str(h): r for h, r in self.per_rung().items()},
        }
