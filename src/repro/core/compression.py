"""Error-feedback int8 compression for sync collectives (beyond-paper).

At an MSF sync point the replicas exchange a parameter *delta* (the local
drift since the last sync). Quantizing that delta to int8 with per-tensor
scales cuts the wire bytes 4× vs fp32 / 2× vs bf16; the quantization error
is carried forward in an error-feedback buffer so it is re-submitted at the
next sync — the standard EF-SGD trick that keeps convergence unbiased.

Wire format per leaf: ``(q int8[shape], scale f32[1])``. The sync itself is
an ``all_gather`` of the int8 payload over the sync axis (gather + local
dequant-average), because summing int8 on the wire would overflow; with the
pod axis size 2 the gather moves ~K·P int8 bytes vs 8·P for an fp32
all-reduce — a 4× collective-term reduction, visible in the §Perf log.

``quantize``/``dequantize`` have a Pallas kernel twin in
``repro.kernels.quant`` (VMEM-tiled pack/unpack); these jnp versions are the
oracle and the default CPU path.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (float) → (q int8, scale f32 scalar). Symmetric per-tensor."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_tree(delta, ef):
    """(delta, ef) → (q_tree, scale_tree, new_ef). delta+ef is quantized."""
    def leaf(d, e):
        v = d.astype(jnp.float32) + e
        q, s = quantize(v)
        return q, s, v - dequantize(q, s)

    out = jax.tree.map(leaf, delta, ef)
    is_t = lambda x: isinstance(x, tuple)
    q_tree = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
    s_tree = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
    new_ef = jax.tree.map(lambda o: o[2], out, is_leaf=is_t)
    return q_tree, s_tree, new_ef


def allgather_mean_dequant(q_tree, s_tree, axis: str, axes_tree=None):
    """All-gather int8 payloads over ``axis`` and average the dequantized
    values locally. Must run inside shard_map with ``axis`` manual.

    ``axes_tree`` (optional): per-leaf logical axes — the gathered f32
    dequant buffer is re-constrained to the parameter's sharding; without
    it XLA loses the layout through the int8 round-trip and materializes
    replicated f32 copies of every leaf (measured: ~550 GB/device on the
    235B config).
    """
    from repro.sharding import current_rules

    rules = current_rules()

    def leaf(q, s, la):
        constrained = (rules is not None and rules.mesh is not None
                       and la is not None)
        if constrained:
            # pin the payload's auto-axis sharding on BOTH sides of the
            # manual gather, or the partitioner replicates the full leaf
            q = jax.lax.with_sharding_constraint(
                q, rules.spec_for(tuple(la), q.shape))
        qs = jax.lax.all_gather(q, axis)          # (K, *shape) int8 on the wire
        ss = jax.lax.all_gather(s, axis)          # (K,) f32
        if constrained:
            qs = jax.lax.with_sharding_constraint(
                qs, rules.spec_for((None,) + tuple(la), qs.shape))
        deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * q.ndim)
        if constrained:
            deq = jax.lax.with_sharding_constraint(
                deq, rules.spec_for((None,) + tuple(la), deq.shape))
        return jnp.mean(deq, axis=0)

    if axes_tree is None:
        axes_tree = jax.tree.map(lambda q: None, q_tree)
    return jax.tree.map(leaf, q_tree, s_tree, axes_tree,
                        is_leaf=lambda x: x is None or not isinstance(x, dict))
