"""MSF auto-tuning — closing the loop the paper left open.

The paper *sweeps* the model synchronization frequency by hand and
observes (i) communication time ∝ sync rate and (ii) accuracy flat across
the explored range. This module picks H automatically from first
principles, so the framework can set the schedule per (model × mesh ×
fabric) without a sweep:

**Cost model.** Per optimizer step,

    T(H) ≈ T_step + T_sync / H                    (blocking sync)
    T(H) ≈ max(T_step · H, T_sync) / H            (overlap="delayed")
    T_sync = wire_bytes(P, K, compression, overlap) / BW_link

with ``T_step`` the compute+memory-bound step time (from the roofline
terms or measured) and ``T_sync`` the parameter-sync collective on the
sync axis (DCN for the hierarchical strategy). Under delayed overlap the
collective runs concurrently with the next block's compute and is exposed
only when it outlasts the block, so ``choose_period`` picks a *smaller* H
(more frequent sync at the same wall clock — tighter averaging for free).
``overlap="chunked"`` keeps the blocking formula but ``T_sync`` shrinks by
the shard count. Wire bytes come from :mod:`repro.core.costmodel`, the
same accounting the sync engine's ``collective_bytes_per_sync`` reports —
one formula, two consumers. Communication efficiency alone is monotone in
H — the paper's Figs 13–15 plateau.

**Statistical guardrail.** Local SGD analysis (Stich 2018; Wang & Joshi
2018) bounds the extra optimization error of H-step averaging by a term
∝ H·η²·σ²; empirically the safe envelope is to keep the *parameter
drift* per block small relative to the parameter scale. We expose this as
``max_drift``: H is capped so that the predicted per-block drift
(η·E[‖g‖]·H, callers pass measured grad/param norms) stays below
``max_drift`` × ‖w‖. With the default 1% drift cap, the paper's own
regime (its largest explored blocks) is comfortably inside the envelope.
Gossip topologies (``SyncConfig.topology`` ∈ {ring, pairwise}) mix only a
factor ``1 − λ₂`` per round (Stich 2018's inexact-averaging regime), so the
cap additionally shrinks by the topology's spectral gap — sparser mixing ⇒
more frequent sync at the same drift budget.

``choose_period`` returns the smallest H whose *remaining* sync overhead
is below ``target_overhead`` of the step time, clipped to the drift cap —
i.e. "as low an MSF as helps, and no lower", the paper's conclusion as an
algorithm.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.config.base import SyncConfig
from repro.core import costmodel

DCN_BW = 6.25e9       # bytes/s per chip, cross-pod
ICI_BW = 50e9         # bytes/s per chip, intra-pod


@dataclasses.dataclass(frozen=True)
class TuneInputs:
    param_bytes_per_chip: int      # sharded parameter bytes on the sync axis
    replicas: int                  # K — sync-axis size (e.g. pods)
    step_time_s: float             # compute/memory-bound time per opt step
    link_bw: float = DCN_BW        # the sync axis' per-chip bandwidth
    grad_norm: float = 1.0         # E‖g‖ (measured or warmup estimate)
    param_norm: float = 1.0        # ‖w‖
    lr: float = 1e-3


def sync_time_s(inp: TuneInputs, cfg: SyncConfig) -> float:
    """One executed parameter sync on the sync axis (ring model, per chip).

    Wire bytes come from the shared cost module — identical to what the
    sync engine's ``collective_bytes_per_sync`` accounts, including the
    compression and chunked-overlap factors.
    """
    wire = costmodel.wire_bytes_per_sync(
        inp.param_bytes_per_chip, max(2, inp.replicas), cfg)
    return wire / inp.link_bw


def drift_cap(inp: TuneInputs, max_drift: float) -> int:
    """Largest H whose predicted per-block drift stays within the cap."""
    per_step_drift = inp.lr * inp.grad_norm / max(inp.param_norm, 1e-12)
    if per_step_drift <= 0:
        return 1 << 16
    return max(1, int(max_drift / per_step_drift))


def choose_period(inp: TuneInputs, cfg: Optional[SyncConfig] = None, *,
                  target_overhead: float = 0.05,
                  max_drift: float = 0.01,
                  overlap: Optional[str] = None,
                  sync_time_override: Optional[float] = None) -> int:
    """Smallest H with *exposed* sync overhead ≤ ``target_overhead``·step
    time, clipped by the statistical drift cap.

    ``overlap`` (or ``cfg.overlap``) changes the overhead condition:
    blocking needs ``T_sync/H ≤ target·T_step``; delayed only needs the
    collective to fit under the next block plus the overhead allowance,
    ``T_sync/H ≤ (1+target)·T_step`` — so delayed H is always ≤ the
    blocking H for the same inputs (more frequent averaging, same wall
    clock).

    ``sync_time_override`` replaces the analytic wire-bytes/bandwidth
    ``T_sync`` with a *measured* collective time (telemetry) — the adaptive
    controller's path: same solver, calibrated inputs.
    """
    cfg = cfg or SyncConfig(strategy="hierarchical")
    if overlap is not None:
        cfg = dataclasses.replace(cfg, overlap=overlap)
    t_sync = (sync_time_override if sync_time_override is not None
              else sync_time_s(inp, cfg))
    if t_sync <= 0 or inp.step_time_s <= 0:
        return 1
    if cfg.overlap == "delayed" or cfg.gossip_async:
        # the collective runs under the next block's compute (async gossip
        # has a full block of slack by construction) and is exposed only
        # when it outlasts the block plus the overhead allowance
        denom = (1.0 + target_overhead) * inp.step_time_s
    else:
        denom = target_overhead * inp.step_time_s
    h_comm = math.ceil(t_sync / denom)
    cap = drift_cap(inp, max_drift)
    if cfg.overlap == "chunked":
        # each leaf only averages every chunks·H steps, so the *effective*
        # averaging period is chunks×H — the drift cap binds H accordingly
        cap = max(1, cap // max(1, cfg.chunks))
    if cfg.topology != "all":
        # gossip convergence guardrail: one round contracts the replica
        # disagreement only by λ₂ (vs 0 for a global average), so reaching
        # the same consensus takes ~1/(1−λ₂) rounds — the effective
        # averaging period is H/(1−λ₂) and the drift cap must bind H at
        # gap·cap. The gossip analog of the chunked ``cap // chunks``.
        # Async gossip additionally mixes 1-round-stale snapshots, which
        # widens the unmixed-drift window by the staleness — the
        # staleness-aware gap halves the cap for the 1-round double buffer.
        gap = costmodel.effective_spectral_gap(
            max(2, inp.replicas), cfg.topology,
            staleness=1 if cfg.gossip_async else 0)
        cap = max(1, int(cap * gap))
    h = max(1, min(h_comm, cap))
    return h


def predicted_step_time(inp: TuneInputs, cfg: SyncConfig, h: int) -> float:
    return costmodel.overlapped_step_time(
        inp.step_time_s, sync_time_s(inp, cfg), h, cfg)


def snap_to_ladder(h: int, ladder) -> int:
    """Nearest ladder rung to ``h`` in log space (geometric ladders make
    "nearest" multiplicative: 6 snaps to 8 on {1,2,4,8}, not to 4).

    Integer-exact: ``h`` is past the lo→hi boundary iff ``h² > lo·hi``
    (the geometric midpoint), so float-log rounding can never flip a
    tie — exact midpoints resolve to the smaller rung (more frequent
    sync is the safe side).
    """
    ladder = sorted(set(int(r) for r in ladder))
    if not ladder:
        raise ValueError("empty ladder")
    h = max(1, int(h))
    best = ladder[0]
    for lo, hi in zip(ladder, ladder[1:]):
        if h * h > lo * hi:
            best = hi
    return best


# ---------------------------------------------------------------------------
# online adaptive MSF: choose_period re-solved from running telemetry
# ---------------------------------------------------------------------------

class AdaptiveController:
    """Closed-loop MSF tuning: re-solve :func:`choose_period` from measured
    ``T_step``/``T_sync`` EMAs every ``adapt_every`` blocks.

    This turns the static tuner into the adaptive sync-interval scheme of
    Keuper & Pfreundt (arXiv:1510.01155): instead of a hand sweep (or one
    analytic guess from nominal bandwidth), the period tracks what the
    fabric and the workload *actually* do — a contended DCN shows up as a
    larger measured ``T_sync`` and the controller raises H; a fast fabric
    lowers it. All of ``choose_period``'s guardrails (drift cap, chunked
    effective-period scaling, gossip spectral-gap cap) still apply because
    it is the same solver — only ``T_sync`` is overridden by telemetry.

    Hysteresis: H only moves when the re-solve differs from the current
    period by more than ``hysteresis`` (relative), so measurement noise
    cannot thrash the schedule. Defaults come from the
    ``SyncConfig.adapt_*`` fields; ``history`` records every ``(block,
    H)`` transition.

    **Ladder mode** (``ladder=(1, 2, 4, …)``): the controller emits moves
    only onto the given rungs — the pre-compiled H ladder of
    :class:`repro.runtime.ladder.LadderRuntime`, where an H change is a
    flush + switch to an already-compiled block (no recompilation). The
    re-solved H snaps to the log-nearest rung and the schedule moves only
    when that rung is at least ``rung_hysteresis`` rungs away from the
    current one (hysteresis in *rung units*; the geometric spacing itself
    absorbs sub-factor-of-two noise, so the relative ``hysteresis`` knob
    is ignored in ladder mode).

    When the telemetry cannot yet separate T_step/T_sync (the LM block
    path sees only whole-block times, and least squares needs two
    distinct H's), the re-solve falls back to the crude per-step time
    with the *analytic* wire-bytes/bandwidth T_sync — enough to make the
    first move, after which the per-rung block times pin the split.

    The driver loop (trainer or :func:`repro.simsync.engine
    .simulate_adaptive`) calls :meth:`observe_block` once per executed
    block and reads back ``.h``::

        ctrl = AdaptiveController(cfg, param_bytes_per_chip=P, replicas=K)
        for block in schedule:
            run_block(h=ctrl.h)
            ctrl.observe_block(step_s=..., sync_s=...)
    """

    def __init__(self, cfg: SyncConfig, *, param_bytes_per_chip: int,
                 replicas: int, link_bw: float = DCN_BW, lr: float = 1e-3,
                 h0: Optional[int] = None,
                 telemetry: Optional["BlockTelemetry"] = None,
                 adapt_every: Optional[int] = None,
                 hysteresis: Optional[float] = None,
                 target_overhead: Optional[float] = None,
                 max_drift: Optional[float] = None,
                 h_max: int = 1024,
                 ladder=None,
                 rung_hysteresis: Optional[int] = None):
        from repro.core.telemetry import BlockTelemetry
        self.cfg = cfg
        self.param_bytes_per_chip = param_bytes_per_chip
        self.replicas = replicas
        self.link_bw = link_bw
        self.lr = lr
        self.telemetry = telemetry or BlockTelemetry()
        self.adapt_every = max(1, adapt_every if adapt_every is not None
                               else cfg.adapt_every)
        self.hysteresis = (hysteresis if hysteresis is not None
                           else cfg.adapt_hysteresis)
        self.target_overhead = (target_overhead if target_overhead is not None
                                else cfg.adapt_target_overhead)
        self.max_drift = (max_drift if max_drift is not None
                          else cfg.adapt_max_drift)
        self.ladder = tuple(sorted(set(int(r) for r in ladder))) \
            if ladder else None
        self.rung_hysteresis = max(1, rung_hysteresis
                                   if rung_hysteresis is not None
                                   else cfg.adapt_rung_hysteresis)
        self.h_max = max(1, h_max if not self.ladder else self.ladder[-1])
        self.h = max(1, min(h0 if h0 is not None else cfg.period,
                            self.h_max))
        if self.ladder:
            self.h = snap_to_ladder(self.h, self.ladder)
        self._grad_norm = _ema_default()
        self._param_norm = _ema_default()
        self._blocks = 0
        self.history = [(0, self.h)]

    def observe_block(self, *, block_s: Optional[float] = None,
                      sync_s: Optional[float] = None,
                      step_s: Optional[float] = None,
                      grad_norm: Optional[float] = None,
                      param_norm: Optional[float] = None) -> int:
        """Feed one block's measurements; returns the (possibly updated) H.

        ``step_s`` is the per-STEP compute time when measured separately
        (timed-step paths); otherwise pass the whole-block ``block_s`` (and
        ``sync_s`` when the collective was instrumented) and the telemetry
        separates the two.
        """
        if step_s is not None:
            self.telemetry.record_step_time(step_s)
            if sync_s is not None:
                self.telemetry.record_sync_time(sync_s)
        elif block_s is not None:
            self.telemetry.record_block(self.h, block_s, sync_s)
        elif sync_s is not None:
            self.telemetry.record_sync_time(sync_s)
        if grad_norm is not None:
            self._grad_norm.update(float(grad_norm))
        if param_norm is not None:
            self._param_norm.update(float(param_norm))
        self._blocks += 1
        if self._blocks % self.adapt_every == 0:
            self._resolve()
        return self.h

    def _resolve(self) -> None:
        est = self.telemetry.estimates()
        if est is None:
            # single-H block telemetry cannot split T_step/T_sync yet —
            # fall back to the (sync-amortized) per-step time + analytic
            # wire T_sync so the first move can happen at all
            t_step = self.telemetry.per_step_s()
            t_sync = None
            if not t_step:
                return
        else:
            t_step, t_sync = est
        if t_step <= 0:
            return
        inp = TuneInputs(
            param_bytes_per_chip=self.param_bytes_per_chip,
            replicas=self.replicas, step_time_s=t_step,
            link_bw=self.link_bw,
            grad_norm=self._grad_norm.value or 1.0,
            param_norm=self._param_norm.value or 1.0, lr=self.lr)
        h_new = min(self.h_max,
                    choose_period(inp, self.cfg,
                                  target_overhead=self.target_overhead,
                                  max_drift=self.max_drift,
                                  sync_time_override=t_sync))
        if self.ladder:
            target = snap_to_ladder(h_new, self.ladder)
            cur = self.ladder.index(self.h)
            tgt = self.ladder.index(target)
            if tgt != cur and abs(tgt - cur) >= self.rung_hysteresis:
                self.h = target
                self.history.append((self._blocks, target))
            return
        if h_new != self.h and abs(h_new - self.h) > self.hysteresis * self.h:
            self.h = h_new
            self.history.append((self._blocks, h_new))

    def to_dict(self) -> dict:
        return {"h": self.h, "blocks": self._blocks,
                "history": list(self.history),
                "telemetry": self.telemetry.to_dict()}


def _ema_default():
    from repro.core.telemetry import EMA
    return EMA(0.9)


def report(inp: TuneInputs, cfg: Optional[SyncConfig] = None) -> dict:
    """Tuning summary across the candidate ladder (for logs/EXPERIMENTS)."""
    cfg = cfg or SyncConfig(strategy="hierarchical")
    h_star = choose_period(inp, cfg)
    ladder = sorted({1, 8, 64, h_star})
    return {
        "sync_time_s": sync_time_s(inp, cfg),
        "chosen_h": h_star,
        "drift_cap": drift_cap(inp, 0.01),
        "ladder": {
            h: {
                "step_s": predicted_step_time(inp, cfg, h),
                # exposed sync fraction — consistent with step_s under
                # overlap (blocking reduces to sync/H/step as before)
                "overhead": (predicted_step_time(inp, cfg, h)
                             - inp.step_time_s) / inp.step_time_s,
            } for h in ladder
        },
    }
