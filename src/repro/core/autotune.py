"""MSF auto-tuning — closing the loop the paper left open.

The paper *sweeps* the model synchronization frequency by hand and
observes (i) communication time ∝ sync rate and (ii) accuracy flat across
the explored range. This module picks H automatically from first
principles, so the framework can set the schedule per (model × mesh ×
fabric) without a sweep:

**Cost model.** Per optimizer step,

    T(H) ≈ T_step + T_sync / H                    (blocking sync)
    T(H) ≈ max(T_step · H, T_sync) / H            (overlap="delayed")
    T_sync = wire_bytes(P, K, compression, overlap) / BW_link

with ``T_step`` the compute+memory-bound step time (from the roofline
terms or measured) and ``T_sync`` the parameter-sync collective on the
sync axis (DCN for the hierarchical strategy). Under delayed overlap the
collective runs concurrently with the next block's compute and is exposed
only when it outlasts the block, so ``choose_period`` picks a *smaller* H
(more frequent sync at the same wall clock — tighter averaging for free).
``overlap="chunked"`` keeps the blocking formula but ``T_sync`` shrinks by
the shard count. Wire bytes come from :mod:`repro.core.costmodel`, the
same accounting the sync engine's ``collective_bytes_per_sync`` reports —
one formula, two consumers. Communication efficiency alone is monotone in
H — the paper's Figs 13–15 plateau.

**Statistical guardrail.** Local SGD analysis (Stich 2018; Wang & Joshi
2018) bounds the extra optimization error of H-step averaging by a term
∝ H·η²·σ²; empirically the safe envelope is to keep the *parameter
drift* per block small relative to the parameter scale. We expose this as
``max_drift``: H is capped so that the predicted per-block drift
(η·E[‖g‖]·H, callers pass measured grad/param norms) stays below
``max_drift`` × ‖w‖. With the default 1% drift cap, the paper's own
regime (its largest explored blocks) is comfortably inside the envelope.
Gossip topologies (``SyncConfig.topology`` ∈ {ring, pairwise}) mix only a
factor ``1 − λ₂`` per round (Stich 2018's inexact-averaging regime), so the
cap additionally shrinks by the topology's spectral gap — sparser mixing ⇒
more frequent sync at the same drift budget.

``choose_period`` returns the smallest H whose *remaining* sync overhead
is below ``target_overhead`` of the step time, clipped to the drift cap —
i.e. "as low an MSF as helps, and no lower", the paper's conclusion as an
algorithm.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.config.base import SyncConfig
from repro.core import costmodel

DCN_BW = 6.25e9       # bytes/s per chip, cross-pod
ICI_BW = 50e9         # bytes/s per chip, intra-pod


@dataclasses.dataclass(frozen=True)
class TuneInputs:
    param_bytes_per_chip: int      # sharded parameter bytes on the sync axis
    replicas: int                  # K — sync-axis size (e.g. pods)
    step_time_s: float             # compute/memory-bound time per opt step
    link_bw: float = DCN_BW        # the sync axis' per-chip bandwidth
    grad_norm: float = 1.0         # E‖g‖ (measured or warmup estimate)
    param_norm: float = 1.0        # ‖w‖
    lr: float = 1e-3


def sync_time_s(inp: TuneInputs, cfg: SyncConfig) -> float:
    """One executed parameter sync on the sync axis (ring model, per chip).

    Wire bytes come from the shared cost module — identical to what the
    sync engine's ``collective_bytes_per_sync`` accounts, including the
    compression and chunked-overlap factors.
    """
    wire = costmodel.wire_bytes_per_sync(
        inp.param_bytes_per_chip, max(2, inp.replicas), cfg)
    return wire / inp.link_bw


def drift_cap(inp: TuneInputs, max_drift: float) -> int:
    """Largest H whose predicted per-block drift stays within the cap."""
    per_step_drift = inp.lr * inp.grad_norm / max(inp.param_norm, 1e-12)
    if per_step_drift <= 0:
        return 1 << 16
    return max(1, int(max_drift / per_step_drift))


def choose_period(inp: TuneInputs, cfg: Optional[SyncConfig] = None, *,
                  target_overhead: float = 0.05,
                  max_drift: float = 0.01,
                  overlap: Optional[str] = None) -> int:
    """Smallest H with *exposed* sync overhead ≤ ``target_overhead``·step
    time, clipped by the statistical drift cap.

    ``overlap`` (or ``cfg.overlap``) changes the overhead condition:
    blocking needs ``T_sync/H ≤ target·T_step``; delayed only needs the
    collective to fit under the next block plus the overhead allowance,
    ``T_sync/H ≤ (1+target)·T_step`` — so delayed H is always ≤ the
    blocking H for the same inputs (more frequent averaging, same wall
    clock).
    """
    cfg = cfg or SyncConfig(strategy="hierarchical")
    if overlap is not None:
        cfg = dataclasses.replace(cfg, overlap=overlap)
    t_sync = sync_time_s(inp, cfg)
    if t_sync <= 0 or inp.step_time_s <= 0:
        return 1
    if cfg.overlap == "delayed":
        denom = (1.0 + target_overhead) * inp.step_time_s
    else:
        denom = target_overhead * inp.step_time_s
    h_comm = math.ceil(t_sync / denom)
    cap = drift_cap(inp, max_drift)
    if cfg.overlap == "chunked":
        # each leaf only averages every chunks·H steps, so the *effective*
        # averaging period is chunks×H — the drift cap binds H accordingly
        cap = max(1, cap // max(1, cfg.chunks))
    if cfg.topology != "all":
        # gossip convergence guardrail: one round contracts the replica
        # disagreement only by λ₂ (vs 0 for a global average), so reaching
        # the same consensus takes ~1/(1−λ₂) rounds — the effective
        # averaging period is H/(1−λ₂) and the drift cap must bind H at
        # gap·cap. The gossip analog of the chunked ``cap // chunks``.
        gap = costmodel.spectral_gap(max(2, inp.replicas), cfg.topology)
        cap = max(1, int(cap * gap))
    h = max(1, min(h_comm, cap))
    return h


def predicted_step_time(inp: TuneInputs, cfg: SyncConfig, h: int) -> float:
    return costmodel.overlapped_step_time(
        inp.step_time_s, sync_time_s(inp, cfg), h, cfg)


def report(inp: TuneInputs, cfg: Optional[SyncConfig] = None) -> dict:
    """Tuning summary across the candidate ladder (for logs/EXPERIMENTS)."""
    cfg = cfg or SyncConfig(strategy="hierarchical")
    h_star = choose_period(inp, cfg)
    ladder = sorted({1, 8, 64, h_star})
    return {
        "sync_time_s": sync_time_s(inp, cfg),
        "chosen_h": h_star,
        "drift_cap": drift_cap(inp, 0.01),
        "ladder": {
            h: {
                "step_s": predicted_step_time(inp, cfg, h),
                # exposed sync fraction — consistent with step_s under
                # overlap (blocking reduces to sync/H/step as before)
                "overhead": (predicted_step_time(inp, cfg, h)
                             - inp.step_time_s) / inp.step_time_s,
            } for h in ladder
        },
    }
