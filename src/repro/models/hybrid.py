"""Zamba2-style hybrid: Mamba2 backbone + a *shared* attention block.

One set of attention+MLP parameters is reused at every application point
(every ``shared_block_every`` backbone layers) — Zamba2's parameter-sharing
trick. Layer grouping: the backbone is split into groups of
``shared_block_every`` Mamba2 layers; after each full group the shared
block runs (the trailing partial group, if any, gets no shared block).
Each application point needs its own KV cache at decode (shared *weights*,
distinct *state*).

Deviation from the published Zamba2 noted in DESIGN.md: the real model
concatenates the block input with the original embeddings (2d → d
projection) before the shared block; we feed the current hidden state
directly. LoRA adapters on the shared block are omitted.

``long_500k`` viability: Mamba2 layers carry O(1) state; the shared-block
caches are seq-length but there are only ``n_layers // shared_block_every``
of them (6 for zamba2-1.2b vs 38), and they shard along ``cache_seq`` over
the model axis with the distributed flash-decode merge.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.losses import ce_loss
from repro.models.transformer import layer_decode, layer_defs, layer_fwd
from repro.sharding import constrain



from repro import flags as _flags


def _scan(*args, **kw):
    kw.setdefault("unroll", _flags.scan_unroll_arg())
    return jax.lax.scan(*args, **kw)

class HybridModel:
    def __init__(self, cfg: ModelConfig, *, scan_layers: bool = True,
                 remat: str = "none", attn_impl: str = "jnp"):
        assert cfg.shared_block_every > 0
        self.cfg = cfg
        self.scan_layers = scan_layers
        self.remat = remat
        self.attn_impl = attn_impl
        self.n_groups = cfg.n_layers // cfg.shared_block_every
        self.tail = cfg.n_layers - self.n_groups * cfg.shared_block_every

    # ----------------------------------------------------------- parameters
    def param_defs(self) -> L.ParamDefs:
        cfg = self.cfg
        block = {
            "ln": L.norm_defs(cfg.d_model, cfg.norm_type),
            "mamba": S.mamba_defs(cfg),
        }
        defs = {
            "embed": L.embed_defs(cfg.vocab_size, cfg.d_model),
            "layers": L.stack_defs(block, cfg.n_layers),
            "shared": layer_defs(cfg),        # ONE attention+MLP block
            "final_norm": L.norm_defs(cfg.d_model, cfg.norm_type),
        }
        defs.update(L.unembed_defs(cfg.vocab_size, cfg.d_model,
                                   cfg.tie_embeddings))
        return defs

    def init(self, key: jax.Array):
        return L.init_params(self.param_defs(), key,
                             dtype=jnp.dtype(self.cfg.param_dtype))

    # ------------------------------------------------------------- forward
    def _mamba_group(self, group_params, x, return_cache: bool):
        def scan_body(carry, lp):
            cfg = self.cfg
            h = L.apply_norm(lp["ln"], carry, cfg.norm_type, cfg.norm_eps)
            out = S.mamba_fwd(lp["mamba"], h, cfg, return_state=return_cache)
            if return_cache:
                out, tails = out
                return carry + out, tails
            fn_out = carry + out
            return fn_out, None

        if self.remat != "none" and not return_cache:
            body = jax.checkpoint(lambda c, p: scan_body(c, p))
        else:
            body = scan_body
        return _scan(body, x, group_params)

    def _group_slices(self, layers_params):
        """Split stacked layer params into per-group views."""
        k = self.cfg.shared_block_every
        groups = []
        for g in range(self.n_groups):
            groups.append(jax.tree.map(
                lambda p: p[g * k:(g + 1) * k], layers_params))
        if self.tail:
            groups.append(jax.tree.map(
                lambda p: p[self.n_groups * k:], layers_params))
        return groups

    def backbone(self, params, x, return_cache: bool = False):
        cfg = self.cfg
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        groups = self._group_slices(params["layers"])
        mamba_caches, attn_caches = [], []
        aux = jnp.float32(0.0)

        for g, gp in enumerate(groups):
            x, tails = self._mamba_group(gp, x, return_cache)
            if return_cache:
                mamba_caches.append(tails)
            if g < self.n_groups:                      # shared block
                out = layer_fwd(params["shared"], x, positions, cfg,
                                mask_mode="causal", prefix_len=0,
                                attn_impl=self.attn_impl,
                                return_kv=return_cache)
                if return_cache:
                    x, a, k, v = out
                    attn_caches.append((k, v))
                else:
                    x, a = out
                aux = aux + a

        x = L.apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
        if return_cache:
            mamba_cache = jax.tree.map(
                lambda *zs: jnp.concatenate(zs, axis=0), *mamba_caches)
            cache = {
                "mamba": mamba_cache,
                "attn_k": jnp.stack([k for k, _ in attn_caches]),
                "attn_v": jnp.stack([v for _, v in attn_caches]),
            }
            return x, cache
        return x

    # ----------------------------------------------------------- train/serve
    def loss(self, params, batch):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = L.embed(params["embed"], batch["tokens"], dtype)
        x = self.backbone(params, x)
        table = params["embed"]["embedding"] if cfg.tie_embeddings \
            else params["out_embedding"]
        loss = ce_loss(x, table, batch["targets"], chunk=cfg.ce_chunk)
        return loss, {"ce": loss}

    def _logits_last(self, params, x_last):
        cfg = self.cfg
        table = params["embed"]["embedding"] if cfg.tie_embeddings \
            else params["out_embedding"]
        logits = jnp.einsum("bd,vd->bv", x_last, table.astype(x_last.dtype))
        return constrain(logits, "batch", "vocab")

    def prefill(self, params, batch):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = L.embed(params["embed"], batch["tokens"], dtype)
        x, cache = self.backbone(params, x, return_cache=True)
        return self._logits_last(params, x[:, -1]), cache

    def init_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        mamba = {k: jnp.zeros(shape, dt) for k, (shape, dt, _) in
                 S.mamba_cache_defs(cfg, batch_size, cfg.n_layers,
                                    dtype).items()}
        attn_shape = (self.n_groups, batch_size, max_len, cfg.n_kv_heads, hd)
        return {
            "mamba": mamba,
            "attn_k": jnp.zeros(attn_shape, dtype),
            "attn_v": jnp.zeros(attn_shape, dtype),
        }

    def decode_step(self, params, batch):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = L.embed(params["embed"], batch["token"], dtype)
        cache, index = batch["cache"], batch["index"]
        k = cfg.shared_block_every

        new_mamba, new_k, new_v = [], [], []
        for g in range(self.n_groups + (1 if self.tail else 0)):
            lo = g * k
            hi = min(lo + k, cfg.n_layers)
            gp = jax.tree.map(lambda p: p[lo:hi], params["layers"])
            gc = jax.tree.map(lambda c: c[lo:hi], cache["mamba"])

            def scan_body(x, layer_in):
                lp, c = layer_in
                h = L.apply_norm(lp["ln"], x, cfg.norm_type, cfg.norm_eps)
                out, nc = S.mamba_decode_step(lp["mamba"], h, c, cfg)
                return x + out, nc

            x, nm = _scan(scan_body, x, (gp, gc))
            new_mamba.append(nm)
            if g < self.n_groups:
                x, nk, nv = layer_decode(params["shared"], x,
                                         cache["attn_k"][g],
                                         cache["attn_v"][g], index, cfg)
                new_k.append(nk)
                new_v.append(nv)

        x = L.apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
        logits = self._logits_last(params, x[:, -1])
        new_cache = {
            "mamba": jax.tree.map(lambda *zs: jnp.concatenate(zs, axis=0),
                                  *new_mamba),
            "attn_k": jnp.stack(new_k),
            "attn_v": jnp.stack(new_v),
        }
        return logits, new_cache

    # ------------------------------------------------------------- layouts
    def input_layout(self, kind: str, batch: int, seq: int) -> Dict[str, Any]:
        cfg = self.cfg
        if kind in ("train", "prefill"):
            out = {"tokens": ((batch, seq), jnp.int32, ("batch", "seq"))}
            if kind == "train":
                out["targets"] = ((batch, seq), jnp.int32, ("batch", "seq"))
            return out
        if kind == "decode":
            hd = cfg.resolved_head_dim
            attn_shape = (self.n_groups, batch, seq, cfg.n_kv_heads, hd)
            attn_axes = A.cache_logical_axes()
            return {
                "token": ((batch, 1), jnp.int32, ("batch", "seq")),
                "cache": {
                    "mamba": S.mamba_cache_defs(cfg, batch, cfg.n_layers,
                                                jnp.dtype(cfg.dtype)),
                    "attn_k": (attn_shape, jnp.dtype(cfg.dtype), attn_axes),
                    "attn_v": (attn_shape, jnp.dtype(cfg.dtype), attn_axes),
                },
                "index": ((), jnp.int32, ()),
            }
        raise ValueError(kind)
