"""Cross-entropy with optional sequence chunking.

Full logits at LM scale are the single biggest activation: (B, S, V) fp32
for qwen3 at train_4k is ~600 GB global. ``chunked_ce`` scans the sequence
in ``chunk``-sized slices, computing logits + log-softmax per slice inside a
``jax.checkpoint`` (so the backward pass recomputes each slice instead of
keeping all of them live). Peak logits memory drops S/chunk ×; FLOPs for
the recompute add one extra logits matmul — the classic memory/compute
trade, accounted for in the roofline's MODEL_FLOPS/HLO_FLOPS ratio.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import constrain



from repro import flags as _flags


def _scan(*args, **kw):
    kw.setdefault("unroll", _flags.scan_unroll_arg())
    return jax.lax.scan(*args, **kw)

def _ce_block(x: jax.Array, table: jax.Array, targets: jax.Array,
              valid: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, C, D) · table: (V, D) · targets: (B, C) → (sum_nll, n_valid)."""
    logits = jnp.einsum("bcd,vd->bcv", x, table.astype(x.dtype))
    logits = constrain(logits, "batch", "seq", "vocab")
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # gather-free target pick (iota-select fuses; take_along_axis is a
    # gather, which the SPMD partitioner mishandles in manual subgroups)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    tgt = jnp.sum(jnp.where(iota == targets[..., None], logits, 0.0),
                  axis=-1)
    nll = (lse - tgt) * valid
    return jnp.sum(nll), jnp.sum(valid)


def ce_loss(x: jax.Array, table: jax.Array, targets: jax.Array,
            mask: Optional[jax.Array] = None, chunk: int = 0) -> jax.Array:
    """Mean next-token NLL. x: (B, S, D) final hidden · table: (V, D).

    ``mask`` (B, S) ∈ {0,1} selects positions contributing to the loss
    (e.g. text-only positions for the VLM). ``chunk`` > 0 scans the seq dim
    in slices of that size (must divide S).
    """
    b, s, d = x.shape
    valid = jnp.ones((b, s), jnp.float32) if mask is None else mask.astype(jnp.float32)

    if chunk <= 0 or s <= chunk or s % chunk != 0:
        total, count = _ce_block(x, table, targets, valid)
        return total / jnp.maximum(count, 1.0)

    nchunk = s // chunk
    xs = x.reshape(b, nchunk, chunk, d).swapaxes(0, 1)          # (n, B, C, D)
    ts = targets.reshape(b, nchunk, chunk).swapaxes(0, 1)
    vs = valid.reshape(b, nchunk, chunk).swapaxes(0, 1)

    block = jax.checkpoint(lambda xc, tc, vc: _ce_block(xc, table, tc, vc))

    def body(carry, inp):
        tot, cnt = carry
        xc, tc, vc = inp
        t, c = block(xc, tc, vc)
        return (tot + t, cnt + c), None

    (total, count), _ = _scan(body, (jnp.float32(0), jnp.float32(0)),
                                     (xs, ts, vs))
    return total / jnp.maximum(count, 1.0)
