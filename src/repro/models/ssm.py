"""Mamba2 (state-space duality) blocks and the attention-free SSM LM.

Block structure (Mamba2, arXiv:2405.21060):

    x, z, B, C, Δ = projections of the input
    x, B, C       = causal depthwise conv (width 4) + SiLU
    y             = SSD(x·heads, Δ, A, B, C) + D∘x          (chunked scan)
    out           = out_proj( RMSNorm(y ⊙ SiLU(z)) )

The train/prefill path uses the *chunked* SSD algorithm (same math as the
Pallas kernel in :mod:`repro.kernels.ssd`, vectorized jnp here so it lowers
on any backend); decode is the exact O(1)-per-step recurrence on a
(B, H, N, P) state — this is what makes the ``long_500k`` cell linear.

Sharding: SSD heads ride the model axis (``ssm_heads``), d_inner
projections ride ``mlp``; the (N,) state dim and B/C projections are
replicated (N = 64..128, negligible).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import layers as L
from repro.models.losses import ce_loss
from repro.sharding import constrain


# ---------------------------------------------------------------------------
# chunked SSD (jnp twin of kernels/ssd)
# ---------------------------------------------------------------------------


from repro import flags as _flags


def _scan(*args, **kw):
    kw.setdefault("unroll", _flags.scan_unroll_arg())
    return jax.lax.scan(*args, **kw)

def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, bm: jax.Array,
                cm: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,L,H,P) · dt: (B,L,H) · a: (H,) · bm/cm: (B,L,N) → (y, state)."""
    b, l, h, p = x.shape
    n = bm.shape[-1]
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0)))
    lp = l + pad
    nc = lp // q

    x32 = x.astype(jnp.float32).reshape(b, nc, q, h, p)
    dt32 = dt.astype(jnp.float32).reshape(b, nc, q, h)
    bm32 = bm.astype(jnp.float32).reshape(b, nc, q, n)
    cm32 = cm.astype(jnp.float32).reshape(b, nc, q, n)
    a32 = a.astype(jnp.float32)

    s0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    rows = jnp.arange(q)[:, None]
    cols = jnp.arange(q)[None, :]
    tri = cols <= rows                                  # (Q, Q)

    # checkpointed: keeps only the (B,H,N,P) carry per chunk in the scan
    # backward; the (b,Q,Q,h) decay tensors are recomputed chunk-by-chunk
    @jax.checkpoint
    def body(state, inp):
        xq, dtq, bq, cq = inp                           # (b,Q,h,p) (b,Q,h) (b,Q,n)
        da = dtq * a32                                  # (b,Q,h)
        cum = jnp.cumsum(da, axis=1)                    # (b,Q,h) inclusive
        total = cum[:, -1]                              # (b,h)

        # mask BEFORE exp: for s > t the raw exponent is large-positive
        # (cum decreases), and exp→inf followed by where(…, 0) still NaNs
        # the backward (inf · 0 cotangent)
        darg = cum[:, :, None, :] - cum[:, None, :, :]            # (b,t,s,h)
        ldec = jnp.exp(jnp.where(tri[None, :, :, None], darg, -60.0))
        ldec = jnp.where(tri[None, :, :, None], ldec, 0.0)
        scores = jnp.einsum("btn,bsn->bts", cq, bq)               # (b,t,s)
        sc = scores[..., None] * ldec * dtq[:, None, :, :]        # (b,t,s,h)
        y = jnp.einsum("btsh,bshp->bthp", sc, xq)

        c_scaled = cq[:, :, None, :] * jnp.exp(cum)[..., None]    # (b,t,h,n)
        y = y + jnp.einsum("bthn,bhnp->bthp", c_scaled, state)

        b_scaled = bq[:, :, None, :] * (dtq * jnp.exp(
            total[:, None, :] - cum))[..., None]                  # (b,s,h,n)
        state = jnp.exp(total)[:, :, None, None] * state + \
            jnp.einsum("bshn,bshp->bhnp", b_scaled, xq)
        return state, y

    xs = (jnp.moveaxis(x32, 1, 0), jnp.moveaxis(dt32, 1, 0),
          jnp.moveaxis(bm32, 1, 0), jnp.moveaxis(cm32, 1, 0))
    state, ys = _scan(body, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, lp, h, p)[:, :l]
    return y.astype(x.dtype), state


def ssd_decode_step(state: jax.Array, xt: jax.Array, dtt: jax.Array,
                    a: jax.Array, bt: jax.Array, ct: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Exact recurrence, one step. state: (B,H,N,P) · xt: (B,H,P) ·
    dtt: (B,H) · bt/ct: (B,N)."""
    decay = jnp.exp(dtt * a[None])                       # (B,H)
    state = state * decay[:, :, None, None] + (
        dtt[:, :, None, None] * bt[:, None, :, None] * xt[:, :, None, :])
    y = jnp.einsum("bn,bhnp->bhp", ct, state)
    return y, state


# ---------------------------------------------------------------------------
# depthwise causal conv
# ---------------------------------------------------------------------------

def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, L, C) · w: (W, C) · b: (C,) → (B, L, C), left-padded causal."""
    width = w.shape[0]
    out = jax.lax.conv_general_dilated(
        x, w[:, None, :],
        window_strides=(1,), padding=[(width - 1, 0)],
        dimension_numbers=("NLC", "LIO", "NLC"),
        feature_group_count=x.shape[-1])
    return out + b


def conv_decode_step(cache: jax.Array, xt: jax.Array, w: jax.Array,
                     b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """cache: (B, W−1, C) past inputs · xt: (B, C) → (yt (B, C), new cache)."""
    window = jnp.concatenate([cache, xt[:, None]], axis=1)   # (B, W, C)
    yt = jnp.einsum("bwc,wc->bc", window, w) + b
    return yt, window[:, 1:]


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def mamba_defs(cfg: ModelConfig, d_model: Optional[int] = None) -> L.ParamDefs:
    d = d_model or cfg.d_model
    s = cfg.ssm
    d_inner = s.expand * d
    h = d_inner // s.head_dim
    n, w = s.state_dim, s.conv_width
    return {
        "in_x": L.Param((d, d_inner), ("embed", "mlp"), init="fan_in"),
        "in_z": L.Param((d, d_inner), ("embed", "mlp"), init="fan_in"),
        "in_b": L.Param((d, n), ("embed", "ssm_state"), init="fan_in"),
        "in_c": L.Param((d, n), ("embed", "ssm_state"), init="fan_in"),
        "in_dt": L.Param((d, h), ("embed", "ssm_heads"), init="fan_in"),
        "dt_bias": L.Param((h,), ("ssm_heads",), init="zeros"),
        "a_log": L.Param((h,), ("ssm_heads",), init="ssm_a"),
        "d_skip": L.Param((h,), ("ssm_heads",), init="ones"),
        "conv_x_w": L.Param((w, d_inner), ("conv", "mlp"), init="fan_in"),
        "conv_x_b": L.Param((d_inner,), ("mlp",), init="zeros"),
        "conv_b_w": L.Param((w, n), ("conv", "ssm_state"), init="fan_in"),
        "conv_b_b": L.Param((n,), ("ssm_state",), init="zeros"),
        "conv_c_w": L.Param((w, n), ("conv", "ssm_state"), init="fan_in"),
        "conv_c_b": L.Param((n,), ("ssm_state",), init="zeros"),
        "gate_norm": L.Param((d_inner,), ("mlp",), init="ones"),
        "out": L.Param((d_inner, d), ("mlp", "embed"), init="fan_in"),
    }


def _project(params, x):
    dtype = x.dtype
    xi = jnp.einsum("bsd,de->bse", x, params["in_x"].astype(dtype))
    z = jnp.einsum("bsd,de->bse", x, params["in_z"].astype(dtype))
    bm = jnp.einsum("bsd,dn->bsn", x, params["in_b"].astype(dtype))
    cm = jnp.einsum("bsd,dn->bsn", x, params["in_c"].astype(dtype))
    dt = jnp.einsum("bsd,dh->bsh", x, params["in_dt"].astype(dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    return xi, z, bm, cm, dt


def mamba_fwd(params, x: jax.Array, cfg: ModelConfig,
              return_state: bool = False):
    """x: (B, S, D) → (out, (ssm_state, conv tails) if return_state)."""
    s = cfg.ssm
    b, l, d = x.shape
    d_inner = params["in_x"].shape[1]
    h = d_inner // s.head_dim

    xi, z, bm, cm, dt = _project(params, x)
    xi = constrain(xi, "batch", "seq", "mlp")

    xi_conv = jax.nn.silu(causal_conv(xi, params["conv_x_w"].astype(xi.dtype),
                                      params["conv_x_b"].astype(xi.dtype)))
    bm_conv = jax.nn.silu(causal_conv(bm, params["conv_b_w"].astype(bm.dtype),
                                      params["conv_b_b"].astype(bm.dtype)))
    cm_conv = jax.nn.silu(causal_conv(cm, params["conv_c_w"].astype(cm.dtype),
                                      params["conv_c_b"].astype(cm.dtype)))

    xh = xi_conv.reshape(b, l, h, s.head_dim)
    xh = constrain(xh, "batch", "seq", "ssm_heads", "head_dim")
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    y, state = ssd_chunked(xh, dt, a, bm_conv, cm_conv, s.chunk_size)
    y = y + params["d_skip"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(b, l, d_inner)

    y = L.rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out"].astype(y.dtype))
    out = constrain(out, "batch", "act_seq", "embed")
    if return_state:
        tails = {
            "ssm": state,                                # (B, H, N, P) f32
            "conv_x": xi[:, -(s.conv_width - 1):],        # pre-conv tails
            "conv_b": bm[:, -(s.conv_width - 1):],
            "conv_c": cm[:, -(s.conv_width - 1):],
        }
        return out, tails
    return out


def mamba_decode_step(params, x: jax.Array, cache: Dict[str, jax.Array],
                      cfg: ModelConfig):
    """x: (B, 1, D) one token. cache: {"ssm","conv_x","conv_b","conv_c"}."""
    s = cfg.ssm
    b = x.shape[0]
    d_inner = params["in_x"].shape[1]
    h = d_inner // s.head_dim

    xi, z, bm, cm, dt = _project(params, x)
    xi, z = xi[:, 0], z[:, 0]
    bm, cm, dt = bm[:, 0], cm[:, 0], dt[:, 0]

    xc, conv_x = conv_decode_step(cache["conv_x"], xi,
                                  params["conv_x_w"].astype(xi.dtype),
                                  params["conv_x_b"].astype(xi.dtype))
    bc, conv_b = conv_decode_step(cache["conv_b"], bm,
                                  params["conv_b_w"].astype(bm.dtype),
                                  params["conv_b_b"].astype(bm.dtype))
    cc, conv_c = conv_decode_step(cache["conv_c"], cm,
                                  params["conv_c_w"].astype(cm.dtype),
                                  params["conv_c_b"].astype(cm.dtype))
    xc, bc, cc = jax.nn.silu(xc), jax.nn.silu(bc), jax.nn.silu(cc)

    xh = xc.reshape(b, h, s.head_dim)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    y, ssm = ssd_decode_step(cache["ssm"], xh.astype(jnp.float32),
                             dt, a, bc.astype(jnp.float32),
                             cc.astype(jnp.float32))
    y = y.astype(x.dtype) + params["d_skip"].astype(x.dtype)[None, :, None] * xh
    y = y.reshape(b, 1, d_inner)

    y = L.rms_norm(y * jax.nn.silu(z)[:, None], params["gate_norm"],
                   cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out"].astype(y.dtype))
    new_cache = {"ssm": ssm, "conv_x": conv_x, "conv_b": conv_b,
                 "conv_c": conv_c}
    return out, new_cache


def mamba_cache_defs(cfg: ModelConfig, batch: int, n_layers: int,
                     dtype) -> Dict[str, Any]:
    """(shape, dtype, logical_axes) per cache leaf, layer-stacked."""
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    h = d_inner // s.head_dim
    w = s.conv_width - 1
    return {
        "ssm": ((n_layers, batch, h, s.state_dim, s.head_dim), jnp.float32,
                ("layers", "batch", "ssm_heads", "ssm_state", "head_dim")),
        "conv_x": ((n_layers, batch, w, d_inner), dtype,
                   ("layers", "batch", "conv", "mlp")),
        "conv_b": ((n_layers, batch, w, s.state_dim), dtype,
                   ("layers", "batch", "conv", "ssm_state")),
        "conv_c": ((n_layers, batch, w, s.state_dim), dtype,
                   ("layers", "batch", "conv", "ssm_state")),
    }


# ---------------------------------------------------------------------------
# attention-free SSM LM (mamba2-2.7b)
# ---------------------------------------------------------------------------

class SSMModel:
    def __init__(self, cfg: ModelConfig, *, scan_layers: bool = True,
                 remat: str = "none", attn_impl: str = "jnp"):
        self.cfg = cfg
        self.scan_layers = scan_layers
        self.remat = remat

    def param_defs(self) -> L.ParamDefs:
        cfg = self.cfg
        block = {
            "ln": L.norm_defs(cfg.d_model, cfg.norm_type),
            "mamba": mamba_defs(cfg),
        }
        defs = {
            "embed": L.embed_defs(cfg.vocab_size, cfg.d_model),
            "layers": L.stack_defs(block, cfg.n_layers),
            "final_norm": L.norm_defs(cfg.d_model, cfg.norm_type),
        }
        defs.update(L.unembed_defs(cfg.vocab_size, cfg.d_model,
                                   cfg.tie_embeddings))
        return defs

    def init(self, key: jax.Array):
        return L.init_params(self.param_defs(), key,
                             dtype=jnp.dtype(self.cfg.param_dtype))

    def _block(self, lp, x, return_state: bool):
        cfg = self.cfg
        h = L.apply_norm(lp["ln"], x, cfg.norm_type, cfg.norm_eps)
        out = mamba_fwd(lp["mamba"], h, cfg, return_state=return_state)
        if return_state:
            out, tails = out
            return x + out, tails
        return x + out

    def backbone(self, params, x, return_cache: bool = False):
        cfg = self.cfg

        def scan_body(carry, lp):
            if return_cache:
                x, tails = self._block(lp, carry, True)
                return x, tails
            fn = lambda c, p: self._block(p, c, False)
            if self.remat != "none":
                fn = jax.checkpoint(fn)
            return fn(carry, lp), None

        if self.scan_layers:
            x, ys = _scan(scan_body, x, params["layers"])
        else:
            ys_list = []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda p: p[i], params["layers"])
                x, y = scan_body(x, lp)
                ys_list.append(y)
            ys = (jax.tree.map(lambda *zs: jnp.stack(zs), *ys_list)
                  if return_cache else None)
        x = L.apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
        return (x, ys) if return_cache else x

    def loss(self, params, batch):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = L.embed(params["embed"], batch["tokens"], dtype)
        x = self.backbone(params, x)
        table = params["embed"]["embedding"] if cfg.tie_embeddings \
            else params["out_embedding"]
        loss = ce_loss(x, table, batch["targets"], chunk=cfg.ce_chunk)
        return loss, {"ce": loss}

    def _logits_last(self, params, x_last):
        cfg = self.cfg
        table = params["embed"]["embedding"] if cfg.tie_embeddings \
            else params["out_embedding"]
        logits = jnp.einsum("bd,vd->bv", x_last, table.astype(x_last.dtype))
        return constrain(logits, "batch", "vocab")

    def prefill(self, params, batch):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = L.embed(params["embed"], batch["tokens"], dtype)
        x, cache = self.backbone(params, x, return_cache=True)
        return self._logits_last(params, x[:, -1]), cache

    def init_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16):
        defs = mamba_cache_defs(self.cfg, batch_size, self.cfg.n_layers, dtype)
        return {k: jnp.zeros(shape, dt) for k, (shape, dt, _) in defs.items()}

    def decode_step(self, params, batch):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = L.embed(params["embed"], batch["token"], dtype)
        cache = batch["cache"]

        def scan_body(x, layer_in):
            lp, c = layer_in
            h = L.apply_norm(lp["ln"], x, cfg.norm_type, cfg.norm_eps)
            out, nc = mamba_decode_step(lp["mamba"], h, c, cfg)
            return x + out, nc

        x, new_cache = _scan(scan_body, x, (params["layers"], cache))
        x = L.apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
        return self._logits_last(params, x[:, -1]), new_cache

    def input_layout(self, kind: str, batch: int, seq: int) -> Dict[str, Any]:
        cfg = self.cfg
        if kind == "train":
            return {
                "tokens": ((batch, seq), jnp.int32, ("batch", "seq")),
                "targets": ((batch, seq), jnp.int32, ("batch", "seq")),
            }
        if kind == "prefill":
            return {"tokens": ((batch, seq), jnp.int32, ("batch", "seq"))}
        if kind == "decode":
            # NOTE: SSM cache is O(1) in seq — `seq` is ignored by layout
            cache = mamba_cache_defs(cfg, batch, cfg.n_layers,
                                     jnp.dtype(cfg.dtype))
            return {
                "token": ((batch, 1), jnp.int32, ("batch", "seq")),
                "cache": cache,
                "index": ((), jnp.int32, ()),
            }
        raise ValueError(kind)
