"""Attention: GQA/MQA/MHA, RoPE, prefix/causal masks, KV-cache decode.

Two distribution regimes:

* **train / prefill** — full-sequence attention; activations sharded
  ``batch→data, heads→model`` via logical constraints; optional Pallas
  flash-attention kernel on TPU (``impl="pallas"``), jnp oracle otherwise.
* **decode** — the KV cache is sharded along *sequence* over the model axis
  (``cache_seq`` rule). A partial-manual ``shard_map`` computes blockwise
  attention per shard and merges with a log-sum-exp ``psum`` — a distributed
  flash-decode. This is what makes 500k-token caches fit (and is the SP
  scheme the hybrid archs use at ``long_500k``).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config.base import ModelConfig
from repro.models import layers as L
from repro.sharding import constrain, current_rules



from repro import flags as _flags


def _scan(*args, **kw):
    kw.setdefault("unroll", _flags.scan_unroll_arg())
    return jax.lax.scan(*args, **kw)

def attn_defs(cfg: ModelConfig, d_model: Optional[int] = None) -> L.ParamDefs:
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    defs: L.ParamDefs = {
        "wq": L.Param((d, cfg.n_heads, hd), ("embed", "heads", "head_dim"), init="fan_in"),
        "wk": L.Param((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim"), init="fan_in"),
        "wv": L.Param((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim"), init="fan_in"),
        "wo": L.Param((cfg.n_heads, hd, d), ("heads", "head_dim", "embed"), init="fan_in"),
    }
    if cfg.qkv_bias:
        defs["bq"] = L.Param((cfg.n_heads, hd), ("heads", "head_dim"), init="zeros")
        defs["bk"] = L.Param((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"), init="zeros")
        defs["bv"] = L.Param((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"), init="zeros")
    return defs


def _project_qkv(params, x, kv_x, cfg: ModelConfig):
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    src = x if kv_x is None else kv_x
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"].astype(dtype))
    if "bq" in params:
        q = q + params["bq"].astype(dtype)
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def make_mask(q_len: int, kv_len: int, mode: str, prefix_len: int = 0,
              q_offset: int = 0) -> Optional[jax.Array]:
    """Boolean (q_len, kv_len) mask; True = attend. ``mode``: causal|prefix|full."""
    if mode == "full":
        return None
    rows = jnp.arange(q_len)[:, None] + q_offset
    cols = jnp.arange(kv_len)[None, :]
    causal = cols <= rows
    if mode == "causal":
        return causal
    if mode == "prefix":
        return causal | (cols < prefix_len)
    raise ValueError(mode)


def _sdpa_jnp(q, k, v, mask) -> jax.Array:
    """Grouped-query scaled-dot-product attention, jnp reference.

    q: (B,S,H,hd) · k/v: (B,T,KV,hd) → (B,S,H,hd). H = KV·G.
    """
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    q = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32)
    scores = scores / (hd ** 0.5)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, hd)


# seq length at/beyond which the q-chunked path replaces full-score SDPA
# (the (B,H,S,S) score tensor at 4k is already GBs/device when an arch's
# head count doesn't divide the model axis and falls back to replication;
# chunking caps scores at (B,H,Q_CHUNK,S) per scan step)
_CHUNK_THRESHOLD = 2048
_Q_CHUNK = 512


def _sdpa_chunked_jnp(q, k, v, mask_mode: str, prefix_len: int,
                      q_chunk: int = _Q_CHUNK) -> jax.Array:
    """Query-chunked SDPA: lax.scan over q blocks, full softmax row per
    block (f32). Scores live at (B,H,q_chunk,T) per step — O(S) not O(S²)
    memory. XLA-lowerable twin of the Pallas flash kernel.

    Head-sharding strategy (the score tensors dominate attention memory
    and compute placement):

    * grouped (B,KV,G,·,·) layout when KV or G divides the model axis
      (qwen3 G=16, zamba KV=32) — keeps GQA's KV bandwidth advantage;
    * flat-head (B,H,·,·) layout with KV broadcast to H when only the
      flat head count divides (phi3.5 H=32 KV=8 G=4, internlm, qwen2.5) —
      XLA cannot shard a dim split across two factors, so the grouped
      layout would replicate or gather here;
    * otherwise (llama 24H, smollm 15H, whisper/paligemma 8H) nothing
      head-like divides: scores replicate across the model axis unless
      the ``attn_q_seq`` rule (context-parallel attention, a §Perf lever)
      shards the q-chunk dim instead.
    """
    from repro.sharding import current_rules

    b, s, h, hd = q.shape
    t = k.shape[1]
    kv = k.shape[2]
    g = h // kv
    if s % q_chunk != 0:
        return _sdpa_jnp(q, k, v, make_mask(s, t, mask_mode, prefix_len))
    nq = s // q_chunk
    cols = jnp.arange(t)[None, :]

    rules = current_rules()
    on_mesh = rules is not None and rules.mesh is not None
    flat_heads = (on_mesh and rules.would_shard("heads", h)
                  and not rules.would_shard("kv_heads", kv)
                  and not rules.would_shard("q_group", g))
    # context-parallel fallback: when NO head-like dim divides the model
    # axis (llama 24H, smollm 15H, whisper/paligemma 8H on a 16-wide
    # axis), shard the q-chunk rows over it instead (act_seq) — otherwise
    # scores replicate 16× in both FLOPs and HBM traffic (§Perf cell A:
    # 7.4× memory-term win). "attn_q_seq" stays as an explicit override.
    q_axis = "attn_q_seq"
    if (on_mesh and not flat_heads and not rules.would_shard("heads", h)
            and not rules.would_shard("kv_heads", kv)
            and not rules.would_shard("q_group", g)
            and not rules.mesh_axes_for("attn_q_seq")):
        q_axis = "act_seq"

    def _mask(scores, iq, extra_dims):
        if mask_mode == "full":
            return scores
        rows = iq * q_chunk + jnp.arange(q_chunk)[:, None]
        m = cols <= rows
        if mask_mode == "prefix":
            m = m | (cols < prefix_len)
        return jnp.where(m[(None,) * extra_dims], scores, -1e30)

    if flat_heads:
        kr = jnp.repeat(k, g, axis=2)       # (B,T,H,hd) — slices of the
        vr = jnp.repeat(v, g, axis=2)       # replicated KV, H-sharded
        kr = constrain(kr, "batch", "seq", "heads", "head_dim")
        vr = constrain(vr, "batch", "seq", "heads", "head_dim")
        qf = q.reshape(b, nq, q_chunk, h, hd).transpose(1, 0, 2, 3, 4)

        @jax.checkpoint
        def block_flat(carry, inp):
            qc, iq = inp                                 # (B,Qc,H,hd)
            qc = constrain(qc, "batch", "attn_q_seq", "heads", "head_dim")
            scores = jnp.einsum("bshd,bthd->bhst", qc,
                                kr).astype(jnp.float32) / (hd ** 0.5)
            scores = _mask(scores, iq, 2)
            scores = constrain(scores, "batch", "heads", "attn_q_seq", None)
            probs = jax.nn.softmax(scores, axis=-1).astype(qc.dtype)
            o = jnp.einsum("bhst,bthd->bshd", probs, vr)
            o = constrain(o, "batch", "attn_q_seq", "heads", "head_dim")
            return carry, o

        _, outs = _scan(block_flat, (), (qf, jnp.arange(nq)))
        return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)

    qg = q.reshape(b, nq, q_chunk, kv, g, hd).transpose(1, 0, 2, 3, 4, 5)

    # checkpointed: without this the scan backward stacks every chunk's
    # scores/probs (≈ the full (B,H,S,S) tensor again); with it the bwd
    # recomputes one chunk at a time — the flash-attention memory profile
    @jax.checkpoint
    def block(carry, inp):
        qc, iq = inp                                     # (B,Qc,KV,G,hd)
        qc = constrain(qc, "batch", q_axis, "kv_heads", "q_group",
                       "head_dim")
        scores = jnp.einsum("bskgd,btkd->bkgst", qc, k).astype(jnp.float32)
        scores = scores / (hd ** 0.5)
        scores = _mask(scores, iq, 3)
        scores = constrain(scores, "batch", "kv_heads", "q_group",
                           q_axis, None)
        probs = jax.nn.softmax(scores, axis=-1).astype(qc.dtype)
        o = jnp.einsum("bkgst,btkd->bskgd", probs, v)
        o = constrain(o, "batch", q_axis, "kv_heads", "q_group",
                      "head_dim")
        return carry, o.reshape(b, q_chunk, h, hd)

    _, outs = _scan(block, (), (qg, jnp.arange(nq)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


def full_attention(params, x: jax.Array, positions: jax.Array, cfg: ModelConfig,
                   mask_mode: str = "causal", prefix_len: int = 0,
                   kv_x: Optional[jax.Array] = None,
                   kv_positions: Optional[jax.Array] = None,
                   impl: str = "jnp", return_kv: bool = False):
    """Training / prefill attention over a full sequence (optionally cross).

    ``return_kv=True`` also returns the (post-RoPE) k, v — the prefill path
    stores them directly as the decode cache.
    """
    q, k, v = _project_qkv(params, x, kv_x, cfg)
    use_rope = kv_x is None  # no RoPE across enc-dec cross attention
    if use_rope:
        cos, sin = rotary_cos_sin(positions, cfg)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, causal=(mask_mode == "causal"),
                                     prefix_len=prefix_len if mask_mode == "prefix" else 0)
    elif q.shape[1] >= _CHUNK_THRESHOLD:
        out = _sdpa_chunked_jnp(q, k, v, mask_mode, prefix_len)
    else:
        mask = make_mask(q.shape[1], k.shape[1], mask_mode, prefix_len)
        out = _sdpa_jnp(q, k, v, mask)
    out = constrain(out, "batch", "seq", "heads", "head_dim")
    y = jnp.einsum("bshd,hdm->bsm", out, params["wo"].astype(x.dtype))
    y = constrain(y, "batch", "act_seq", "embed")
    if return_kv:
        return y, k, v
    return y


def rotary_cos_sin(positions, cfg: ModelConfig):
    return L.rotary_cos_sin(positions, cfg.resolved_head_dim, cfg.rope_theta)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
               dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    hd = cfg.resolved_head_dim
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_logical_axes() -> Tuple[str, ...]:
    return ("layers", "batch", "cache_seq", "kv_heads", "head_dim")


def _decode_attn_chunk(q, k_chunk, v_chunk, index, chunk_offset):
    """Per-shard flash-decode partial: returns (o, l, m) to be lse-merged.

    q: (B,1,KV,G,hd) · k/v_chunk: (B,Sc,KV,hd); positions chunk_offset+i
    valid iff <= index.
    """
    sc = k_chunk.shape[1]
    scores = jnp.einsum("bqkgd,btkd->bkgqt", q, k_chunk).astype(jnp.float32)
    scores = scores / (q.shape[-1] ** 0.5)
    pos = chunk_offset + jnp.arange(sc)
    valid = pos <= index
    scores = jnp.where(valid[None, None, None, None, :], scores, -jnp.inf)
    m = jnp.max(scores, axis=-1, keepdims=True)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(scores - m_safe)
    p = jnp.where(jnp.isfinite(scores), p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(v_chunk.dtype), v_chunk)
    return o, l, m_safe, jnp.isfinite(m)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     index: jax.Array, mesh=None, seq_shard_axis: str = "model"
                     ) -> jax.Array:
    """One-token attention against a sequence-sharded cache.

    q: (B,1,H,hd); k/v_cache: (B,S,KV,hd) sharded (data, model, -, -).
    Merges per-shard partials with an lse-combine over ``seq_shard_axis``.
    Falls back to single-shard math when no mesh/axis available.
    """
    b, _, h, hd = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    qg = q.reshape(b, 1, kv, g, hd)

    rules = current_rules()
    mesh = mesh or (rules.mesh if rules else None)
    n_shards = (dict(zip(mesh.axis_names, mesh.devices.shape)).get(
        seq_shard_axis, 1) if mesh is not None else 1)
    s_total = k_cache.shape[1]
    if mesh is None or n_shards <= 1 or s_total % n_shards != 0:
        # single-shard math (no mesh, or a cache length that doesn't tile
        # the model axis, e.g. whisper's 1500-frame cross cache)
        o, l, m, has = _decode_attn_chunk(qg, k_cache, v_cache, index, 0)
        out = (o / jnp.maximum(l, 1e-30)).astype(q.dtype)
        return out.reshape(b, 1, h, hd)

    chunk = s_total // n_shards

    def shard_fn(qg, k_chunk, v_chunk, index):
        shard_id = jax.lax.axis_index(seq_shard_axis)
        o, l, m, _ = _decode_attn_chunk(qg, k_chunk, v_chunk, index,
                                        shard_id * chunk)
        # lse merge across shards — all-reduce payloads kept f32 (XLA's
        # bf16 AllReducePromotion pass CHECK-crashes on these ARs)
        m_glob = jax.lax.pmax(m, seq_shard_axis)
        scale = jnp.exp(m - m_glob)
        l_glob = jax.lax.psum(l * scale, seq_shard_axis)
        o_glob = jax.lax.psum(o.astype(jnp.float32) * scale, seq_shard_axis)
        return (o_glob / jnp.maximum(l_glob, 1e-30)).astype(qg.dtype)

    fn = jax.shard_map(
        shard_fn,                   # context mesh (nests under pod-manual)
        in_specs=(P(), P(None, seq_shard_axis), P(None, seq_shard_axis), P()),
        out_specs=P(),
        check_vma=False, axis_names={seq_shard_axis})
    out = fn(qg, k_cache, v_cache, index)
    return out.reshape(b, 1, h, hd)


def decode_step_attention(params, x: jax.Array, cache_k: jax.Array,
                          cache_v: jax.Array, index: jax.Array,
                          cfg: ModelConfig,
                          cross: bool = False) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token attention step; returns (y, new_k, new_v).

    x: (B,1,d). cache_k/v: (B,S,KV,hd). ``cross=True`` skips cache update &
    RoPE (whisper cross-attention against fixed encoder states).
    """
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    if "bq" in params:
        q = q + params["bq"].astype(dtype)
    if not cross:
        k_new = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
        v_new = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
        if "bk" in params:
            k_new = k_new + params["bk"].astype(dtype)
            v_new = v_new + params["bv"].astype(dtype)
        pos = jnp.full((x.shape[0], 1), index, jnp.int32)
        cos, sin = rotary_cos_sin(pos, cfg)
        q = L.apply_rope(q, cos, sin)
        k_new = L.apply_rope(k_new, cos, sin)
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k_new.astype(cache_k.dtype), index, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v_new.astype(cache_v.dtype), index, axis=1)
        eff_index = index
    else:
        eff_index = cache_k.shape[1] - 1  # attend over the whole encoder output
    out = decode_attention(q, cache_k, cache_v, eff_index)
    y = jnp.einsum("bshd,hdm->bsm", out, params["wo"].astype(dtype))
    return constrain(y, "batch", "seq", "embed"), cache_k, cache_v
