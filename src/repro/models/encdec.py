"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the brief, the conv frontend is a STUB: ``input_layout`` expects
precomputed frame embeddings (B, n_audio_frames, d_model) where the real
model would run its two conv layers over mel spectrograms. Everything
downstream — encoder self-attention stack, decoder with causal
self-attention + cross-attention, tied unembedding — is real.

Decode caches: per-decoder-layer self KV (grows with generated length) and
cross KV (computed once at prefill from the encoder output, then frozen).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models.losses import ce_loss
from repro.sharding import constrain



from repro import flags as _flags


def _scan(*args, **kw):
    kw.setdefault("unroll", _flags.scan_unroll_arg())
    return jax.lax.scan(*args, **kw)

def _enc_layer_defs(cfg: ModelConfig) -> L.ParamDefs:
    return {
        "ln1": L.norm_defs(cfg.d_model, cfg.norm_type),
        "attn": A.attn_defs(cfg),
        "ln2": L.norm_defs(cfg.d_model, cfg.norm_type),
        "mlp": L.mlp_defs(cfg.d_model, cfg.d_ff),
    }


def _dec_layer_defs(cfg: ModelConfig) -> L.ParamDefs:
    return {
        "ln1": L.norm_defs(cfg.d_model, cfg.norm_type),
        "self_attn": A.attn_defs(cfg),
        "ln_x": L.norm_defs(cfg.d_model, cfg.norm_type),
        "cross_attn": A.attn_defs(cfg),
        "ln2": L.norm_defs(cfg.d_model, cfg.norm_type),
        "mlp": L.mlp_defs(cfg.d_model, cfg.d_ff),
    }


class EncDecModel:
    def __init__(self, cfg: ModelConfig, *, scan_layers: bool = True,
                 remat: str = "none", attn_impl: str = "jnp"):
        assert cfg.n_encoder_layers > 0 and cfg.n_audio_frames > 0
        self.cfg = cfg
        self.scan_layers = scan_layers
        self.remat = remat
        self.attn_impl = attn_impl

    # ----------------------------------------------------------- parameters
    def param_defs(self) -> L.ParamDefs:
        cfg = self.cfg
        return {
            "embed": L.embed_defs(cfg.vocab_size, cfg.d_model),
            "enc_layers": L.stack_defs(_enc_layer_defs(cfg),
                                       cfg.n_encoder_layers),
            "enc_norm": L.norm_defs(cfg.d_model, cfg.norm_type),
            "dec_layers": L.stack_defs(_dec_layer_defs(cfg), cfg.n_layers),
            "final_norm": L.norm_defs(cfg.d_model, cfg.norm_type),
        }

    def init(self, key: jax.Array):
        return L.init_params(self.param_defs(), key,
                             dtype=jnp.dtype(self.cfg.param_dtype))

    # -------------------------------------------------------------- encoder
    def encode(self, params, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = frames.astype(jnp.dtype(cfg.dtype))
        b, t, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))

        def body(carry, lp):
            h = L.apply_norm(lp["ln1"], carry, cfg.norm_type, cfg.norm_eps)
            h = A.full_attention(lp["attn"], h, positions, cfg,
                                 mask_mode="full", impl=self.attn_impl)
            x = carry + h
            h = L.apply_norm(lp["ln2"], x, cfg.norm_type, cfg.norm_eps)
            return x + L.mlp(lp["mlp"], h), None

        if self.remat != "none":
            body = jax.checkpoint(body)
        x, _ = _scan(body, x, params["enc_layers"])
        return L.apply_norm(params["enc_norm"], x, cfg.norm_type, cfg.norm_eps)

    # -------------------------------------------------------------- decoder
    def _dec_layer(self, lp, x, positions, enc_out, return_kv: bool):
        cfg = self.cfg
        h = L.apply_norm(lp["ln1"], x, cfg.norm_type, cfg.norm_eps)
        out = A.full_attention(lp["self_attn"], h, positions, cfg,
                               mask_mode="causal", impl=self.attn_impl,
                               return_kv=return_kv)
        if return_kv:
            out, sk, sv = out
        x = x + out
        h = L.apply_norm(lp["ln_x"], x, cfg.norm_type, cfg.norm_eps)
        out = A.full_attention(lp["cross_attn"], h, positions, cfg,
                               mask_mode="full", kv_x=enc_out,
                               impl=self.attn_impl, return_kv=return_kv)
        if return_kv:
            out, ck, cv = out
        x = x + out
        h = L.apply_norm(lp["ln2"], x, cfg.norm_type, cfg.norm_eps)
        x = x + L.mlp(lp["mlp"], h)
        if return_kv:
            return x, (sk, sv, ck, cv)
        return x

    def decode_fwd(self, params, tokens, enc_out, return_cache: bool = False):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = L.embed(params["embed"], tokens, dtype)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        def body(carry, lp):
            out = self._dec_layer(lp, carry, positions, enc_out, return_cache)
            if return_cache:
                x, kv = out
                return x, kv
            return out, None

        if self.remat != "none" and not return_cache:
            body = jax.checkpoint(body)
        x, kvs = _scan(body, x, params["dec_layers"])
        x = L.apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
        return (x, kvs) if return_cache else x

    # ----------------------------------------------------------- train/serve
    def loss(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        x = self.decode_fwd(params, batch["tokens"], enc_out)
        loss = ce_loss(x, params["embed"]["embedding"], batch["targets"],
                       chunk=cfg.ce_chunk)
        return loss, {"ce": loss}

    def _logits_last(self, params, x_last):
        logits = jnp.einsum("bd,vd->bv", x_last,
                            params["embed"]["embedding"].astype(x_last.dtype))
        return constrain(logits, "batch", "vocab")

    def prefill(self, params, batch):
        enc_out = self.encode(params, batch["frames"])
        x, (sk, sv, ck, cv) = self.decode_fwd(params, batch["tokens"],
                                              enc_out, return_cache=True)
        cache = {"self_k": sk, "self_v": sv, "cross_k": ck, "cross_v": cv}
        return self._logits_last(params, x[:, -1]), cache

    def init_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        self_shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, hd)
        cross_shape = (cfg.n_layers, batch_size, cfg.n_audio_frames,
                       cfg.n_kv_heads, hd)
        return {
            "self_k": jnp.zeros(self_shape, dtype),
            "self_v": jnp.zeros(self_shape, dtype),
            "cross_k": jnp.zeros(cross_shape, dtype),
            "cross_v": jnp.zeros(cross_shape, dtype),
        }

    def decode_step(self, params, batch):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = L.embed(params["embed"], batch["token"], dtype)
        cache, index = batch["cache"], batch["index"]

        def body(x, layer_in):
            lp, sk, sv, ck, cv = layer_in
            h = L.apply_norm(lp["ln1"], x, cfg.norm_type, cfg.norm_eps)
            out, sk, sv = A.decode_step_attention(lp["self_attn"], h, sk, sv,
                                                  index, cfg)
            x = x + out
            h = L.apply_norm(lp["ln_x"], x, cfg.norm_type, cfg.norm_eps)
            out, _, _ = A.decode_step_attention(lp["cross_attn"], h, ck, cv,
                                                index, cfg, cross=True)
            x = x + out
            h = L.apply_norm(lp["ln2"], x, cfg.norm_type, cfg.norm_eps)
            return x + L.mlp(lp["mlp"], h), (sk, sv)

        x, (nsk, nsv) = _scan(
            body, x, (params["dec_layers"], cache["self_k"], cache["self_v"],
                      cache["cross_k"], cache["cross_v"]))
        x = L.apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
        logits = self._logits_last(params, x[:, -1])
        new_cache = dict(cache, self_k=nsk, self_v=nsv)
        return logits, new_cache

    # ------------------------------------------------------------- layouts
    def input_layout(self, kind: str, batch: int, seq: int) -> Dict[str, Any]:
        cfg = self.cfg
        d = cfg.d_model
        frames = ((batch, cfg.n_audio_frames, d), jnp.dtype(cfg.dtype),
                  ("batch", "seq", "embed"))
        if kind == "train":
            return {
                "frames": frames,
                "tokens": ((batch, seq), jnp.int32, ("batch", "seq")),
                "targets": ((batch, seq), jnp.int32, ("batch", "seq")),
            }
        if kind == "prefill":
            return {
                "frames": frames,
                "tokens": ((batch, seq), jnp.int32, ("batch", "seq")),
            }
        if kind == "decode":
            hd = cfg.resolved_head_dim
            axes = A.cache_logical_axes()
            self_shape = (cfg.n_layers, batch, seq, cfg.n_kv_heads, hd)
            cross_shape = (cfg.n_layers, batch, cfg.n_audio_frames,
                           cfg.n_kv_heads, hd)
            dt = jnp.dtype(cfg.dtype)
            return {
                "token": ((batch, 1), jnp.int32, ("batch", "seq")),
                "cache": {
                    "self_k": (self_shape, dt, axes),
                    "self_v": (self_shape, dt, axes),
                    "cross_k": (cross_shape, dt, axes),
                    "cross_v": (cross_shape, dt, axes),
                },
                "index": ((), jnp.int32, ()),
            }
        raise ValueError(kind)
