"""Mixture-of-Experts FFN: top-k routing with scatter/gather dispatch (EP).

Dispatch layout: tokens are scattered into a static (E, C, D) expert buffer
(C = capacity per expert), expert matmuls run as dense (E, C, D)×(E, D, F)
einsums with the expert dim sharded over the model axis (expert
parallelism), and outputs gather back to token order.

Why scatter and not the Mesh-TF one-hot-einsum dispatch: the dispatch
tensor there is (T, E, C), which at qwen3-train_4k scale (T = 1M tokens,
E = 128, C = 82k) is ~10¹⁶ elements. The scatter formulation keeps every
intermediate at O(T·k·D) — the (E, C, D) buffer itself is the largest
object and shards over (experts→model, embed→data).

Position-in-queue is a cumsum over the flattened (T·k, E) one-hot (Switch
Transformer style); tokens over capacity are dropped by scatter
``mode="drop"`` (out-of-bounds position ⇒ no write), matching
capacity-dropping semantics. FLOPs scale with top_k·capacity_factor, not
num_experts — the roofline sees *active* compute.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import layers as L
from repro.sharding import constrain


def moe_defs(cfg: ModelConfig) -> L.ParamDefs:
    e, d, f = cfg.moe.num_experts, cfg.d_model, cfg.d_ff
    # expert tensors use their own d_model logical name so a serving
    # layout can replicate dense weights over data (TP-only) while the
    # expert tables stay 2-D sharded (experts×data)
    return {
        "router": L.Param((d, e), ("embed", "experts"), init="fan_in"),
        "w_gate": L.Param((e, d, f), ("experts", "expert_embed", "expert_mlp"), init="fan_in"),
        "w_up": L.Param((e, d, f), ("experts", "expert_embed", "expert_mlp"), init="fan_in"),
        "w_down": L.Param((e, f, d), ("experts", "expert_mlp", "expert_embed"), init="fan_in"),
    }


def _top_k_routing(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """logits: (T, E) → (weights (T, k) renormalized, indices (T, k))."""
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, indices = jax.lax.top_k(gates, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, indices


def moe_ffn(params, x: jax.Array, cfg: ModelConfig,
            capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (out (B, S, D), aux load-balance loss scalar).

    Two execution paths:

    * **GShard shard_map path** (big token counts on a real mesh): manual
      all-to-all dispatch to expert-owner shards. XLA's SPMD partitioner
      handles data-dependent gather/scatter by replicating the (T, D)
      token tensor and all-reducing it — at 1M tokens that is ~17 GB × a
      dozen buffers per device (measured; see EXPERIMENTS.md §Perf). The
      manual path keeps every scatter local and moves exactly the
      dispatched tokens: 2 all-to-alls + the FSDP weight all-gathers.
    * **jnp scatter path** (single device / decode-sized T): the oracle
      the shard_map path is tested against; pathology-free at small T.
    """
    from repro.sharding import current_rules

    b, s, d = x.shape
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    t = b * s

    rules = current_rules()
    if rules is not None and rules.mesh is not None:
        if t >= 32768:
            mesh = rules.mesh
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            d_ax = rules.mesh_axes_for("batch")   # 1-2 axes: (pod?, data)
            m_ax = rules.mesh_axes_for("act_seq")
            dsz = 1
            for a in d_ax:
                dsz *= sizes[a]
            if (len(d_ax) >= 1 and len(m_ax) == 1
                    and e % sizes[m_ax[0]] == 0
                    and d % sizes[d_ax[-1]] == 0
                    and b % dsz == 0
                    and s % sizes[m_ax[0]] == 0):
                return _moe_ffn_sharded(params, x, cfg, capacity_factor,
                                        mesh, tuple(d_ax), m_ax[0])
        else:
            # decode-scale T on a real mesh: dense one-hot dispatch —
            # every op is an einsum (the partitioner mishandles
            # scatter/gather in manual subgroups), and the (T, E, C)
            # dispatch tensor is tiny at this scale
            return _moe_ffn_onehot(params, x, cfg, capacity_factor)

    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt, params["router"].astype(x.dtype))
    weights, indices = _top_k_routing(logits, k)          # (T,k) f32 / i32

    # Switch-style load-balance aux: mean gate mass × token fraction per E
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(gates, axis=0)
    onehot_any = jax.nn.one_hot(indices, e, dtype=jnp.float32).sum(1)  # (T,E)
    ce = jnp.mean(onehot_any, axis=0) / k
    aux = e * jnp.sum(me * ce)

    capacity = int(max(8, capacity_factor * k * t / e))
    capacity = -(-capacity // 8) * 8                      # sublane-aligned

    # position of each (token, slot) within its expert queue. The (T·k, E)
    # one-hot cumsum is ordered slot-major-within-token (row t·k + j), so
    # ranks are consistent across the per-slot loops below.
    flat_e = indices.reshape(t * k)                       # (T·k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)   # (T·k, E)
    pos_all = jnp.cumsum(onehot, axis=0) - 1              # inclusive rank − 1
    pos_all = jnp.take_along_axis(
        pos_all, flat_e[:, None], axis=1)[:, 0].reshape(t, k)

    # scatter per slot (k static loop) — avoids the (T·k, D) repeat blowup;
    # each (T, D) intermediate shards 2-D over (data×model) via `tokens`
    xt = constrain(xt, "tokens", None)
    buf = jnp.zeros((e, capacity, d), x.dtype)
    for j in range(k):
        buf = buf.at[indices[:, j], pos_all[:, j]].add(xt, mode="drop")
    buf = constrain(buf, "experts", "expert_cap", None)

    gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(x.dtype))
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(gate) * up
    h = constrain(h, "experts", "expert_cap", "expert_mlp")
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(x.dtype))
    ye = constrain(ye, "experts", "expert_cap", None)

    # gather back per slot; dropped (over-capacity) slots contribute 0
    out = jnp.zeros((t, d), x.dtype)
    for j in range(k):
        pos_j = pos_all[:, j]
        kept = (pos_j < capacity).astype(weights.dtype)
        yt = ye[indices[:, j], jnp.minimum(pos_j, capacity - 1)]   # (T, D)
        yt = constrain(yt, "tokens", None)
        out = out + yt * (weights[:, j] * kept)[:, None].astype(yt.dtype)
    out = out.reshape(b, s, d)
    return constrain(out, "batch", "act_seq", "embed"), aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# GShard-style expert parallelism (manual collectives)
# ---------------------------------------------------------------------------

def _moe_ffn_sharded(params, x: jax.Array, cfg: ModelConfig,
                     capacity_factor: float, mesh, data_axes,
                     model_axis: str) -> Tuple[jax.Array, jax.Array]:
    """Full-manual shard_map over (data, model).

    Layout: tokens sharded (batch→data, seq→model); experts owned by model
    shards (E_loc = E/M each); expert weights FSDP-sharded over data on
    the d_model dim. Per (data, model) shard:

      route local tokens → scatter into a (E, C_s, D) send buffer
      → all-to-all over model (tokens travel to their expert's owner)
      → all-gather expert weights over data (FSDP) → expert matmuls
      → reverse all-to-all → local weighted combine.

    C_s = per-(expert, source-shard) capacity = ⌈cf·k·T_loc/E⌉, so global
    capacity matches the jnp path's ⌈cf·k·T/E⌉ in expectation. Wire cost:
    2 × (E·C_s·D) bytes per shard per direction — the honest MoE a2a.
    """
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    if isinstance(data_axes, str):
        data_axes = (data_axes,)
    fsdp_axis = data_axes[-1]        # weights FSDP-shard over the last one
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msz = sizes[model_axis]
    dsz = 1
    for a in data_axes:
        dsz *= sizes[a]
    e_loc = e // msz
    t_loc = (b // dsz) * (s // msz)
    cap = int(max(8, capacity_factor * k * t_loc / e))
    cap = -(-cap // 8) * 8

    def body(x_loc, router, wg, wu, wd):
        b_loc, s_loc, _ = x_loc.shape
        tl = b_loc * s_loc
        xt = x_loc.reshape(tl, d)

        logits = jnp.einsum("td,de->te", xt, router.astype(xt.dtype))
        weights, indices = _top_k_routing(logits, k)

        gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        me = jax.lax.pmean(jnp.mean(gates, axis=0),
                           data_axes + (model_axis,))
        onehot_any = jax.nn.one_hot(indices, e, dtype=jnp.float32).sum(1)
        ce = jax.lax.pmean(jnp.mean(onehot_any, axis=0) / k,
                           data_axes + (model_axis,))
        aux = e * jnp.sum(me * ce)

        # local ranks within each expert queue
        flat_e = indices.reshape(tl * k)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - 1
        pos = jnp.take_along_axis(
            pos, flat_e[:, None], axis=1)[:, 0].reshape(tl, k)

        # local scatter into the send buffer (E, C_s, D)
        buf = jnp.zeros((e, cap, d), x_loc.dtype)
        for j in range(k):
            buf = buf.at[indices[:, j], pos[:, j]].add(xt, mode="drop")

        # dispatch: tokens travel to their expert's owner shard
        buf = buf.reshape(msz, e_loc, cap, d)
        recv = jax.lax.all_to_all(buf, model_axis, 0, 0, tiled=True)
        xe = recv.reshape(msz, e_loc, cap, d).transpose(1, 0, 2, 3)
        xe = xe.reshape(e_loc, msz * cap, d)          # (E_loc, C_eff, D)

        # FSDP: gather the d_model shards of this shard's expert weights
        wg_f = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
        wu_f = jax.lax.all_gather(wu, fsdp_axis, axis=1, tiled=True)
        wd_f = jax.lax.all_gather(wd, fsdp_axis, axis=2, tiled=True)

        dt = xe.dtype
        gate = jnp.einsum("ecd,edf->ecf", xe, wg_f.astype(dt))
        up = jnp.einsum("ecd,edf->ecf", xe, wu_f.astype(dt))
        h = jax.nn.silu(gate) * up
        ye = jnp.einsum("ecf,efd->ecd", h, wd_f.astype(dt))

        # return trip
        ye = ye.reshape(e_loc, msz, cap, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(ye, model_axis, 0, 0, tiled=True)
        ye_all = back.reshape(e, cap, d)

        # local weighted combine; over-capacity slots contribute 0
        out = jnp.zeros((tl, d), x_loc.dtype)
        for j in range(k):
            pos_j = pos[:, j]
            kept = (pos_j < cap).astype(weights.dtype)
            yt = ye_all[indices[:, j], jnp.minimum(pos_j, cap - 1)]
            out = out + yt * (weights[:, j] * kept)[:, None].astype(yt.dtype)
        return out.reshape(b_loc, s_loc, d), aux

    bspec = data_axes if len(data_axes) > 1 else data_axes[0]
    shmapped = jax.shard_map(
        body,                       # context mesh (nests under pod-manual)
        in_specs=(P(bspec, model_axis, None),            # x
                  P(None, None),                          # router (gathered)
                  P(model_axis, fsdp_axis, None),         # w_gate (E, D, F)
                  P(model_axis, fsdp_axis, None),         # w_up
                  P(model_axis, None, fsdp_axis)),        # w_down (E, F, D)
        out_specs=(P(bspec, model_axis, None), P()),
        axis_names=set(data_axes) | {model_axis}, check_vma=False)

    out, aux = shmapped(x, params["router"], params["w_gate"],
                        params["w_up"], params["w_down"])
    from repro.sharding import constrain
    out = constrain(out, "batch", "act_seq", "embed")
    return out, aux.astype(jnp.float32)


def _moe_ffn_onehot(params, x: jax.Array, cfg: ModelConfig,
                    capacity_factor: float) -> Tuple[jax.Array, jax.Array]:
    """Dense one-hot dispatch (Mesh-TF style) — decode-scale T only.

    The (T, E, C) dispatch/combine tensors make this formulation
    quadratic-memory at training scale, but at decode (T ≤ a few k) they
    are KBs and every op partitions cleanly as an einsum.
    """
    b, s, d = x.shape
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt, params["router"].astype(x.dtype))
    weights, indices = _top_k_routing(logits, k)

    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(gates, axis=0)
    onehot_e = jax.nn.one_hot(indices, e, dtype=jnp.float32)   # (T, k, E)
    ce = jnp.mean(onehot_e.sum(1), axis=0) / k
    aux = e * jnp.sum(me * ce)

    cap = int(max(8, capacity_factor * k * t / e))
    cap = -(-cap // 8) * 8

    # rank within expert queue, computed entirely with reductions
    flat = onehot_e.reshape(t * k, e)
    pos = jnp.cumsum(flat, axis=0) - 1.0                      # (T·k, E)
    pos = jnp.sum(pos * flat, axis=1).reshape(t, k)           # (T, k)
    kept = (pos < cap).astype(jnp.float32)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap,
                            dtype=jnp.float32)                # (T, k, C)

    disp = jnp.einsum("tke,tkc->tec", onehot_e, pos_oh * kept[..., None])
    comb = jnp.einsum("tke,tkc->tec", onehot_e,
                      pos_oh * (weights * kept)[..., None])

    dt = x.dtype
    xe = jnp.einsum("td,tec->ecd", xt, disp.astype(dt))
    gate = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(dt))
    up = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(dt))
    h = jax.nn.silu(gate) * up
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dt))
    out = jnp.einsum("ecd,tec->td", ye, comb.astype(dt)).reshape(b, s, d)
    return constrain(out, "batch", "act_seq", "embed"), aux.astype(jnp.float32)
