"""Model registry: family → class, plus exact analytic parameter counts.

``analytic_param_count`` sums the model's own ``param_defs()`` shape
declarations, so it is exact by construction (no separate bookkeeping to
drift). ``active_only=True`` scales MoE expert tensors by top_k/E — the
MODEL_FLOPS = 6·N_active·D roofline convention.
"""
from __future__ import annotations

import math
from typing import Any

import jax

from repro.config.base import ModelConfig
from repro.models import layers as L


def _families():
    from repro.models.encdec import EncDecModel
    from repro.models.hybrid import HybridModel
    from repro.models.ssm import SSMModel
    from repro.models.transformer import DecoderLM, PrefixVLM

    return {
        "dense": DecoderLM,
        "moe": DecoderLM,
        "vlm": PrefixVLM,
        "ssm": SSMModel,
        "hybrid": HybridModel,
        "audio": EncDecModel,
    }


def build_model(cfg: ModelConfig, *, scan_layers: bool = True,
                remat: str = "none", attn_impl: str = "jnp") -> Any:
    fams = _families()
    if cfg.family not in fams:
        raise KeyError(f"unknown family {cfg.family!r}; known {sorted(fams)}")
    return fams[cfg.family](cfg, scan_layers=scan_layers, remat=remat,
                            attn_impl=attn_impl)


def analytic_param_count(cfg: ModelConfig, active_only: bool = False,
                         include_embeddings: bool = True) -> int:
    model = build_model(cfg)
    defs = model.param_defs()
    total = 0
    flat, _ = jax.tree_util.tree_flatten_with_path(
        defs, is_leaf=lambda x: isinstance(x, L.Param))
    for path, p in flat:
        keys = [str(getattr(e, "key", "")) for e in path]
        n = math.prod(p.shape)
        if not include_embeddings and any("embed" in k and "layers" not in k
                                          for k in keys[:1]):
            continue
        if active_only and "experts" in p.logical:
            # expert-parallel tensors: only top_k of num_experts are active
            n = int(n * cfg.moe.top_k / max(1, cfg.moe.num_experts))
        total += n
    return int(total)
