"""Decoder-only transformer LM (dense + MoE) and the prefix-LM VLM variant.

Unified model API (duck-typed, shared by every family in the registry):

    param_defs()                      → nested dict of Param declarations
    init(key)                         → param pytree
    loss(params, batch)               → (scalar, metrics dict)   [train_*]
    prefill(params, batch)            → (last_logits, cache)     [prefill_*]
    decode_step(params, batch)        → (logits, new_cache)      [decode_*]
    init_cache(batch, max_len, dtype) → cache pytree
    input_layout(kind, B, S)          → {name: (shape, dtype, logical_axes)}

The layer stack is ``lax.scan`` over stacked layer params (compact HLO —
one layer body regardless of depth, which is what keeps 94-layer dry-run
compiles tractable), with optional per-layer ``jax.checkpoint`` (remat).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models.losses import ce_loss
from repro.sharding import constrain

REMAT_POLICIES = {
    "none": None,
    "full": "full",
    "dots": "dots",
}



from repro import flags as _flags


def _scan(*args, **kw):
    kw.setdefault("unroll", _flags.scan_unroll_arg())
    return jax.lax.scan(*args, **kw)

def _maybe_remat(fn, remat: str):
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def layer_defs(cfg: ModelConfig) -> L.ParamDefs:
    defs: L.ParamDefs = {
        "ln1": L.norm_defs(cfg.d_model, cfg.norm_type),
        "attn": A.attn_defs(cfg),
        "ln2": L.norm_defs(cfg.d_model, cfg.norm_type),
    }
    if cfg.is_moe:
        defs["moe"] = M.moe_defs(cfg)
    else:
        defs["mlp"] = L.mlp_defs(cfg.d_model, cfg.d_ff)
    return defs


def layer_fwd(lp, x: jax.Array, positions: jax.Array, cfg: ModelConfig,
              mask_mode: str, prefix_len: int, attn_impl: str,
              return_kv: bool = False):
    """One transformer block. Returns (x, aux, (k, v) if return_kv)."""
    h = L.apply_norm(lp["ln1"], x, cfg.norm_type, cfg.norm_eps)
    attn_out = A.full_attention(lp["attn"], h, positions, cfg,
                                mask_mode=mask_mode, prefix_len=prefix_len,
                                impl=attn_impl, return_kv=return_kv)
    if return_kv:
        attn_out, k, v = attn_out
    x = x + attn_out
    h = L.apply_norm(lp["ln2"], x, cfg.norm_type, cfg.norm_eps)
    if cfg.is_moe:
        ffn_out, aux = M.moe_ffn(lp["moe"], h, cfg)
    else:
        ffn_out, aux = L.mlp(lp["mlp"], h), jnp.float32(0.0)
    x = x + ffn_out
    if return_kv:
        return x, aux, k, v
    return x, aux


def layer_decode(lp, x, cache_k, cache_v, index, cfg: ModelConfig):
    """One block, single-token decode. Returns (x, new_k, new_v)."""
    h = L.apply_norm(lp["ln1"], x, cfg.norm_type, cfg.norm_eps)
    attn_out, cache_k, cache_v = A.decode_step_attention(
        lp["attn"], h, cache_k, cache_v, index, cfg)
    x = x + attn_out
    h = L.apply_norm(lp["ln2"], x, cfg.norm_type, cfg.norm_eps)
    if cfg.is_moe:
        ffn_out, _ = M.moe_ffn(lp["moe"], h, cfg)
    else:
        ffn_out = L.mlp(lp["mlp"], h)
    return x + ffn_out, cache_k, cache_v


class DecoderLM:
    """Dense or MoE decoder-only LM."""

    family_mask = "causal"

    def __init__(self, cfg: ModelConfig, *, scan_layers: bool = True,
                 remat: str = "none", attn_impl: str = "jnp"):
        self.cfg = cfg
        self.scan_layers = scan_layers
        self.remat = remat
        self.attn_impl = attn_impl

    # ----------------------------------------------------------- parameters
    def param_defs(self) -> L.ParamDefs:
        cfg = self.cfg
        defs = {
            "embed": L.embed_defs(cfg.vocab_size, cfg.d_model),
            "layers": L.stack_defs(layer_defs(cfg), cfg.n_layers),
            "final_norm": L.norm_defs(cfg.d_model, cfg.norm_type),
        }
        defs.update(L.unembed_defs(cfg.vocab_size, cfg.d_model,
                                   cfg.tie_embeddings))
        return defs

    def init(self, key: jax.Array):
        return L.init_params(self.param_defs(), key,
                             dtype=jnp.dtype(self.cfg.param_dtype))

    # ------------------------------------------------------------- forward
    def _prefix_len(self, batch) -> int:
        return 0

    def _embed_inputs(self, params, batch) -> jax.Array:
        dtype = jnp.dtype(self.cfg.dtype)
        return L.embed(params["embed"], batch["tokens"], dtype)

    def backbone(self, params, x: jax.Array, prefix_len: int = 0,
                 return_cache: bool = False):
        """x: (B, S, D) embedded inputs → final hidden (+ cache)."""
        cfg = self.cfg
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        mask_mode = "prefix" if prefix_len else "causal"

        body = functools.partial(
            layer_fwd, cfg=cfg, positions=positions, mask_mode=mask_mode,
            prefix_len=prefix_len, attn_impl=self.attn_impl,
            return_kv=return_cache)

        def scan_body(carry, lp):
            out = _maybe_remat(lambda c, p: body(p, c), self.remat)(carry, lp)
            if return_cache:
                x, aux, k, v = out
                return x, (aux, k, v)
            x, aux = out
            return x, (aux,)

        if self.scan_layers:
            x, ys = _scan(scan_body, x, params["layers"])
        else:
            ys_list = []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda p: p[i], params["layers"])
                x, y = scan_body(x, lp)
                ys_list.append(y)
            ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys_list)

        x = L.apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
        aux = jnp.mean(ys[0])
        if return_cache:
            cache = {"k": ys[1], "v": ys[2]}  # (L, B, S, KV, hd)
            return x, aux, cache
        return x, aux

    # --------------------------------------------------------------- train
    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        prefix = self._prefix_len(batch)
        x, aux = self.backbone(params, x, prefix_len=prefix)
        table = params["embed"]["embedding"] if cfg.tie_embeddings \
            else params["out_embedding"]
        mask = batch.get("loss_mask")
        loss = ce_loss(x, table, batch["targets"], mask=mask,
                       chunk=cfg.ce_chunk)
        total = loss + cfg.moe.load_balance_coef * aux if cfg.is_moe else loss
        metrics = {"ce": loss}
        if cfg.is_moe:
            metrics["aux"] = aux
        return total, metrics

    # ------------------------------------------------------------- serving
    def _logits_last(self, params, x_last: jax.Array) -> jax.Array:
        cfg = self.cfg
        table = params["embed"]["embedding"] if cfg.tie_embeddings \
            else params["out_embedding"]
        logits = jnp.einsum("bd,vd->bv", x_last, table.astype(x_last.dtype))
        return constrain(logits, "batch", "vocab")

    def prefill(self, params, batch):
        x = self._embed_inputs(params, batch)
        x, _, cache = self.backbone(params, x,
                                    prefix_len=self._prefix_len(batch),
                                    return_cache=True)
        return self._logits_last(params, x[:, -1]), cache

    def init_cache(self, batch_size: int, max_len: int,
                   dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
        return A.init_cache(self.cfg, batch_size, max_len, self.cfg.n_layers,
                            dtype)

    def decode_step(self, params, batch):
        """batch: {"token": (B,1) i32, "cache": {...}, "index": i32[]}"""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = L.embed(params["embed"], batch["token"], dtype)
        cache, index = batch["cache"], batch["index"]

        def scan_body(x, layer_in):
            lp, ck, cv = layer_in
            x, nk, nv = layer_decode(lp, x, ck, cv, index, cfg)
            return x, (nk, nv)

        x, (nk, nv) = _scan(scan_body, x,
                                   (params["layers"], cache["k"], cache["v"]))
        x = L.apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
        logits = self._logits_last(params, x[:, -1])
        return logits, {"k": nk, "v": nv}

    # ------------------------------------------------------------- layouts
    def input_layout(self, kind: str, batch: int, seq: int
                     ) -> Dict[str, Any]:
        cfg = self.cfg
        if kind == "train":
            return {
                "tokens": ((batch, seq), jnp.int32, ("batch", "seq")),
                "targets": ((batch, seq), jnp.int32, ("batch", "seq")),
            }
        if kind == "prefill":
            return {
                "tokens": ((batch, seq), jnp.int32, ("batch", "seq")),
            }
        if kind == "decode":
            hd = cfg.resolved_head_dim
            cache_shape = (cfg.n_layers, batch, seq, cfg.n_kv_heads, hd)
            cache_axes = A.cache_logical_axes()
            return {
                "token": ((batch, 1), jnp.int32, ("batch", "seq")),
                "cache": {
                    "k": (cache_shape, jnp.dtype(cfg.dtype), cache_axes),
                    "v": (cache_shape, jnp.dtype(cfg.dtype), cache_axes),
                },
                "index": ((), jnp.int32, ()),
            }
        raise ValueError(kind)


class PrefixVLM(DecoderLM):
    """PaliGemma-style VLM: stubbed SigLIP patch embeddings as a prefix, a
    gemma-style decoder backbone, prefix-LM attention (bidirectional over
    the image prefix), CE on text positions only.

    ``seq`` in every shape cell is the TOTAL length (image prefix + text).
    """

    def _prefix_len(self, batch) -> int:
        return self.cfg.num_image_tokens

    def _embed_inputs(self, params, batch) -> jax.Array:
        dtype = jnp.dtype(self.cfg.dtype)
        text = L.embed(params["embed"], batch["tokens"], dtype)
        patches = batch["patches"].astype(dtype)      # (B, P, D) stub frontend
        x = jnp.concatenate([patches, text], axis=1)
        return constrain(x, "batch", "act_seq", "embed")

    def loss(self, params, batch):
        """targets cover the text positions: (B, S_text)."""
        cfg = self.cfg
        p = cfg.num_image_tokens
        x = self._embed_inputs(params, batch)
        x, aux = self.backbone(params, x, prefix_len=p)
        x_text = x[:, p:]                             # predict text only
        table = params["embed"]["embedding"] if cfg.tie_embeddings \
            else params["out_embedding"]
        loss = ce_loss(x_text, table, batch["targets"], chunk=cfg.ce_chunk)
        return loss, {"ce": loss}

    def prefill(self, params, batch):
        x = self._embed_inputs(params, batch)
        x, _, cache = self.backbone(params, x,
                                    prefix_len=self.cfg.num_image_tokens,
                                    return_cache=True)
        return self._logits_last(params, x[:, -1]), cache

    def input_layout(self, kind: str, batch: int, seq: int) -> Dict[str, Any]:
        cfg = self.cfg
        p = cfg.num_image_tokens
        s_text = max(1, seq - p)
        d = cfg.d_model
        if kind == "train":
            return {
                "tokens": ((batch, s_text), jnp.int32, ("batch", "seq")),
                "targets": ((batch, s_text), jnp.int32, ("batch", "seq")),
                "patches": ((batch, p, d), jnp.dtype(cfg.dtype),
                            ("batch", "seq", "embed")),
            }
        if kind == "prefill":
            return {
                "tokens": ((batch, s_text), jnp.int32, ("batch", "seq")),
                "patches": ((batch, p, d), jnp.dtype(cfg.dtype),
                            ("batch", "seq", "embed")),
            }
        return super().input_layout(kind, batch, seq)
