"""Shared building blocks: param-def system, norms, RoPE, embeddings, MLPs.

Parameters are plain pytrees (nested dicts of ``jnp.ndarray``). Every leaf is
declared through a :class:`Param` so the matching *logical sharding axes*
tree can be derived mechanically (``axes_of``) and stays in sync with shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import constrain


@dataclasses.dataclass(frozen=True)
class Param:
    """Declaration of one parameter leaf."""

    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"       # normal | zeros | ones | fan_in
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


ParamDefs = Dict[str, Any]  # nested dict of Param


def _init_leaf(p: Param, key, dtype) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "ssm_a":
        # Mamba2 A init: A = −exp(a_log) spread over [1, 16]
        h = p.shape[-1]
        return jnp.broadcast_to(
            jnp.log(jnp.linspace(1.0, 16.0, h)), p.shape).astype(dtype)
    if p.init == "fan_in":
        import math
        fan_in = p.shape[0] if len(p.shape) == 1 else math.prod(p.shape[:-1])
        scale = 1.0 / max(1.0, fan_in) ** 0.5
        return (jax.random.normal(key, p.shape) * scale).astype(dtype)
    return (jax.random.normal(key, p.shape) * p.scale).astype(dtype)


def init_params(defs: ParamDefs, key: jax.Array, dtype=jnp.float32):
    """Materialize a param pytree from defs; deterministic per-leaf keys."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, Param))
    keys = jax.random.split(key, max(1, len(leaves)))
    arrs = [_init_leaf(p, k, dtype) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def axes_of(defs: ParamDefs):
    """Logical-axes pytree matching ``init_params`` output."""
    return jax.tree.map(lambda p: p.logical, defs,
                        is_leaf=lambda x: isinstance(x, Param))


def shapes_of(defs: ParamDefs):
    return jax.tree.map(lambda p: p.shape, defs,
                        is_leaf=lambda x: isinstance(x, Param))


def stack_defs(defs: ParamDefs, n: int) -> ParamDefs:
    """Prepend a scanned ``layers`` dim of size n to every leaf."""
    return jax.tree.map(
        lambda p: Param((n,) + p.shape, ("layers",) + p.logical, p.init, p.scale),
        defs, is_leaf=lambda x: isinstance(x, Param))


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


def rms_norm_defs(d: int) -> Param:
    return Param((d,), ("embed",), init="ones")


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dtype)


def norm_defs(d: int, norm_type: str = "rms") -> ParamDefs:
    if norm_type == "layer":
        return {"scale": Param((d,), ("embed",), init="ones"),
                "bias": Param((d,), ("embed",), init="zeros")}
    return {"scale": Param((d,), ("embed",), init="ones")}


def apply_norm(params, x: jax.Array, norm_type: str, eps: float) -> jax.Array:
    if norm_type == "layer":
        return layer_norm(x, params["scale"], params["bias"], eps)
    return rms_norm(x, params["scale"], eps)


def rotary_cos_sin(positions: jax.Array, head_dim: int, theta: float,
                   dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """positions: (...,) int32 → cos/sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(angles).astype(dtype), jnp.sin(angles).astype(dtype)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def embed_defs(vocab: int, d_model: int) -> ParamDefs:
    return {"embedding": Param((vocab, d_model), ("vocab", "embed"))}


def embed(params, tokens: jax.Array, dtype) -> jax.Array:
    """Token embedding lookup.

    Three paths. XLA's SPMD gather partitioning CHECK-crashes when a
    sharded-table gather sits inside a manual (pod) subgroup at 512
    devices, so on a real mesh we never hand the partitioner a gather:

    * big T  → manual vocab-parallel lookup (Megatron-style masked local
      gather + ``psum_scatter`` over the vocab axis, emitting the
      act_seq-sharded layout directly);
    * small T (decode) → one-hot einsum (gather-free, partitions like any
      matmul; flops negligible at decode scale);
    * no mesh (CPU tests) → plain gather.
    """
    from repro.sharding import current_rules

    table = params["embedding"]
    v, d = table.shape
    b, s = tokens.shape
    rules = current_rules()
    if rules is not None and rules.mesh is not None:
        mesh = rules.mesh
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        v_ax = rules.mesh_axes_for("vocab")
        d_ax = rules.mesh_axes_for("embed")
        if (b * s >= 32768 and len(v_ax) == 1
                and v % sizes[v_ax[0]] == 0 and s % sizes[v_ax[0]] == 0
                and b % (sizes[d_ax[0]] if d_ax else 1) == 0
                and (not d_ax or d % sizes[d_ax[0]] == 0)):
            return _embed_sharded(table, tokens, dtype, mesh,
                                  d_ax[0] if d_ax else None, v_ax[0])
        oh = jax.nn.one_hot(tokens, v, dtype=dtype)
        out = jnp.einsum("bsv,vd->bsd", oh, table.astype(dtype))
        return constrain(out, "batch", "act_seq", "embed")
    out = table.astype(dtype)[tokens]
    return constrain(out, "batch", "act_seq", "embed")


def _embed_sharded(table: jax.Array, tokens: jax.Array, dtype, mesh,
                   data_axis, model_axis) -> jax.Array:
    """Manual vocab-parallel embedding under full-manual shard_map."""
    from jax.sharding import PartitionSpec as P

    def body(tok, tab):
        # tok: (B_loc, S) · tab: (V_loc, D_loc)
        if data_axis is not None:
            tab = jax.lax.all_gather(tab, data_axis, axis=1, tiled=True)
        v_loc = tab.shape[0]
        lo = jax.lax.axis_index(model_axis) * v_loc
        ids = tok - lo
        ok = (ids >= 0) & (ids < v_loc)
        x = tab[jnp.clip(ids, 0, v_loc - 1)].astype(jnp.float32)
        x = jnp.where(ok[..., None], x, 0.0)
        # sum the per-vocab-shard partials, scattering seq → act_seq layout.
        # f32 payload: XLA's bf16 AllReducePromotion pass CHECK-crashes on
        # cross-pod bf16 reductions (same bug as the flash-decode merge).
        x = jax.lax.psum_scatter(x, model_axis, scatter_dimension=1,
                                 tiled=True)
        return x.astype(dtype)

    axes = {model_axis} | ({data_axis} if data_axis else set())
    tok_spec = P(data_axis, None) if data_axis else P(None, None)
    tab_spec = P(model_axis, data_axis)
    out_spec = P(data_axis, model_axis, None)
    # mesh=None → use the context mesh: inside an outer (pod-manual)
    # shard_map the context is an AbstractMesh with pod already Manual,
    # and passing the concrete mesh is rejected
    fn = jax.shard_map(body, in_specs=(tok_spec, tab_spec),
                       out_specs=out_spec, axis_names=axes, check_vma=False)
    out = fn(tokens, table)
    return constrain(out, "batch", "act_seq", "embed")


def unembed(params, x: jax.Array, tied: bool) -> jax.Array:
    table = params["embedding"] if tied else params["out_embedding"]
    logits = jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype))
    return constrain(logits, "batch", "seq", "vocab")


def unembed_defs(vocab: int, d_model: int, tied: bool) -> ParamDefs:
    if tied:
        return {}
    return {"out_embedding": Param((vocab, d_model), ("vocab", "embed"))}


# ---------------------------------------------------------------------------
# dense (SwiGLU) MLP
# ---------------------------------------------------------------------------

def mlp_defs(d_model: int, d_ff: int) -> ParamDefs:
    return {
        "w_gate": Param((d_model, d_ff), ("embed", "mlp"), init="fan_in"),
        "w_up": Param((d_model, d_ff), ("embed", "mlp"), init="fan_in"),
        "w_down": Param((d_ff, d_model), ("mlp", "embed"), init="fan_in"),
    }


def mlp(params, x: jax.Array) -> jax.Array:
    dtype = x.dtype
    gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dtype))
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(dtype))
    h = jax.nn.silu(gate) * up
    h = constrain(h, "batch", "seq", "mlp")
    out = jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(dtype))
    return constrain(out, "batch", "act_seq", "embed")
