"""Compatibility shims: run the new-style JAX API on older jaxlib.

The codebase is written against the post-0.6 JAX surface (``jax.shard_map``,
``jax.set_mesh``, ``jax.sharding.AxisType``, ``jax.make_mesh(axis_types=)``,
``jax.jit(in_shardings=PartitionSpec)``). On older installs (0.4.x) those
names are missing but the underlying machinery exists under
``jax.experimental.shard_map`` (with the ``auto`` parameter playing the role
of the complement of ``axis_names``) and the legacy mesh context manager.
This module backfills the new names once, at ``repro`` import time; on a
new-enough JAX it is a no-op.

Legacy-only behavior changes (documented, performance-neutral on tests):

* ``with_sharding_constraint`` becomes a no-op *inside* a shard_map body:
  0.4.x XLA's partitioner CHECK-fails (``IsManualSubgroup``) on auto-axis
  constraints in partial-manual regions. Constraints are layout hints, not
  semantics, so dropping them is safe (single-host test meshes don't need
  them).
* ``jax.jit`` with ``PartitionSpec`` leaves in in_/out_shardings resolves
  them against the ambient mesh lazily at first call/lower, mirroring the
  new API's context-mesh resolution.
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
import inspect

import jax
import jax.sharding

LEGACY = not hasattr(jax, "set_mesh")

_IN_SHARD_MAP = contextvars.ContextVar("repro_in_shard_map", default=False)


def _ambient_mesh():
    """The legacy thread-resources mesh set by ``with mesh:`` (None if unset)."""
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


if not hasattr(jax.sharding, "AxisType"):
    class _AxisType:
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = _AxisType  # type: ignore[attr-defined]


if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
    _make_mesh = jax.make_mesh

    def _make_mesh_compat(axis_shapes, axis_names, *, devices=None,
                          axis_types=None):
        del axis_types  # pre-AxisType meshes are implicitly Auto
        return _make_mesh(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = _make_mesh_compat  # type: ignore[assignment]


if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map
    from jax.sharding import PartitionSpec as _PSpec

    def _spec_entries(spec):
        """P(...) → list of (dim, (axis, ...)) for the named entries."""
        out = []
        for dim, entry in enumerate(tuple(spec)):
            if entry is None:
                continue
            out.append((dim, entry if isinstance(entry, tuple) else (entry,)))
        return out

    def _inline_shard_map(f, in_specs, out_specs):
        """Emulate a shard_map nested inside an outer manual region.

        Legacy shard_map cannot nest under an already-manual trace, but the
        nested region's collectives are legal in the outer one (its axes are
        manual there). So: slice each operand to this device's shard by
        ``axis_index``, run the body inline, and all-gather named output
        dims back to the outer region's (replicated) layout.
        """
        def to_local(x, spec):
            if spec is None or not isinstance(spec, _PSpec):
                return x
            for dim, axes in _spec_entries(spec):
                idx = None
                size = 1
                for a in axes:
                    ai = jax.lax.axis_index(a)
                    n = jax.lax.psum(1, a)
                    idx = ai if idx is None else idx * n + ai
                    size = size * n
                shard = x.shape[dim] // size
                x = jax.lax.dynamic_slice_in_dim(x, idx * shard, shard,
                                                 axis=dim)
            return x

        def to_global(y, spec):
            if spec is None or not isinstance(spec, _PSpec):
                return y
            for dim, axes in reversed(_spec_entries(spec)):
                for a in reversed(axes):
                    y = jax.lax.all_gather(y, a, axis=dim, tiled=True)
            return y

        def call(*args):
            # PartitionSpec is a pytree leaf, so mapping (args, specs)
            # pairs arrays with their specs at matching tree positions
            locs = jax.tree.map(to_local, tuple(args), tuple(in_specs))
            outs = f(*locs)
            return jax.tree.map(to_global, outs, out_specs)
        return call

    def _shard_map_compat(f, mesh=None, *, in_specs, out_specs,
                          axis_names=None, check_vma=True):
        if _IN_SHARD_MAP.get():
            # nested under an outer manual region — emulate inline
            return _inline_shard_map(f, in_specs, out_specs)
        if mesh is None:
            mesh = _ambient_mesh()
            if mesh is None:
                raise ValueError(
                    "shard_map without mesh requires an ambient mesh "
                    "(jax.set_mesh) on legacy JAX")
        # partial-manual (auto axes) CHECK-crashes 0.4.x XLA
        # (IsManualSubgroup); run full-manual instead — unnamed dims are
        # simply replicated across the extra manual axes, which is
        # semantics-preserving because the body never references them.
        del axis_names

        @functools.wraps(f)
        def traced(*args, **kwargs):
            token = _IN_SHARD_MAP.set(True)
            try:
                return f(*args, **kwargs)
            finally:
                _IN_SHARD_MAP.reset(token)

        return _shard_map(traced, mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)

    jax.shard_map = _shard_map_compat  # type: ignore[attr-defined]


if not hasattr(jax, "set_mesh"):
    @contextlib.contextmanager
    def _set_mesh(mesh):
        # the legacy Mesh context manager supplies the resource env that
        # with_sharding_constraint(PartitionSpec) and pjit resolve against
        with mesh:
            yield mesh

    jax.set_mesh = _set_mesh  # type: ignore[attr-defined]


if LEGACY:
    # --- with_sharding_constraint: drop inside shard_map bodies ----------
    _orig_wsc = jax.lax.with_sharding_constraint

    def _wsc_compat(x, shardings):
        if _IN_SHARD_MAP.get():
            return x
        return _orig_wsc(x, shardings)

    jax.lax.with_sharding_constraint = _wsc_compat  # type: ignore[assignment]

    # --- jit: resolve PartitionSpec shardings against the ambient mesh ---
    from jax.sharding import NamedSharding, PartitionSpec as _P

    _orig_jit = jax.jit

    def _has_spec(tree) -> bool:
        return any(isinstance(l, _P) for l in jax.tree.leaves(
            tree, is_leaf=lambda x: isinstance(x, _P)))

    def _resolve_specs(tree, mesh):
        return jax.tree.map(
            lambda l: NamedSharding(mesh, l) if isinstance(l, _P) else l,
            tree, is_leaf=lambda x: isinstance(x, _P))

    class _LazySpecJit:
        """jit whose PartitionSpec shardings bind to the mesh in scope at
        first call/lower (new-JAX context-mesh semantics)."""

        def __init__(self, fun, kwargs):
            self._fun = fun
            self._kwargs = kwargs
            self._cache = {}

        def _resolved(self):
            mesh = _ambient_mesh()
            if mesh is None:
                raise ValueError(
                    "jit with PartitionSpec shardings requires an ambient "
                    "mesh (jax.set_mesh) on legacy JAX")
            key = id(mesh)
            if key not in self._cache:
                kw = dict(self._kwargs)
                for name in ("in_shardings", "out_shardings"):
                    if name in kw:
                        kw[name] = _resolve_specs(kw[name], mesh)
                self._cache[key] = _orig_jit(self._fun, **kw)
            return self._cache[key]

        def __call__(self, *args, **kwargs):
            return self._resolved()(*args, **kwargs)

        def lower(self, *args, **kwargs):
            return self._resolved().lower(*args, **kwargs)

        def __getattr__(self, name):
            return getattr(self._resolved(), name)

    @functools.wraps(_orig_jit)
    def _jit_compat(fun=None, **kwargs):
        if fun is None:
            return lambda f: _jit_compat(f, **kwargs)
        if _has_spec((kwargs.get("in_shardings"), kwargs.get("out_shardings"))):
            return _LazySpecJit(fun, kwargs)
        return _orig_jit(fun, **kwargs)

    jax.jit = _jit_compat  # type: ignore[assignment]
