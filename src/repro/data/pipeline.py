"""Sharded, resumable data pipeline.

Production shape: the pipeline owns an integer cursor (`state()` /
`restore()` round-trips through the checkpoint manager), produces
globally-consistent batches deterministically from (seed, step), and places
them on device with the batch sharding the mesh expects. Host sharding is
index-based: in a multi-process run each process materializes only its
addressable slice (``process_slice``); in this single-process environment
the slice is the whole batch, but the code path is the multi-host one.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.config.base import DataConfig, ModelConfig
from repro.data.synthetic import synthetic_lm_batch


class DataPipeline:
    def __init__(self, data_cfg: DataConfig, model_cfg: ModelConfig,
                 batch_sharding: Optional[Any] = None,
                 start_step: int = 0):
        self.cfg = data_cfg
        self.model_cfg = model_cfg
        self.batch_sharding = batch_sharding
        self._step = int(start_step)

    # -- checkpointable cursor ------------------------------------------------
    def state(self) -> Dict[str, int]:
        return {"step": self._step}

    def restore(self, state: Dict[str, int]) -> None:
        self._step = int(state["step"])

    # -- batch production -----------------------------------------------------
    def _host_batch(self, step: int) -> Dict[str, np.ndarray]:
        return synthetic_lm_batch(
            step,
            global_batch=self.cfg.global_batch,
            seq_len=self.cfg.seq_len,
            vocab_size=self.model_cfg.vocab_size,
            seed=self.cfg.seed,
        )

    def process_slice(self, batch: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """The rows this process contributes (multi-host index sharding)."""
        n_proc = jax.process_count()
        if n_proc == 1:
            return batch
        b = self.cfg.global_batch
        per = b // n_proc
        lo = jax.process_index() * per
        return {k: v[lo:lo + per] for k, v in batch.items()}

    def next_host(self) -> Dict[str, np.ndarray]:
        """Advance the cursor and return the host (numpy) batch.

        The H-ladder block assembly stacks microbatches on host with
        numpy and feeds the result straight to a pre-compiled executable:
        no eager jnp op may run there, or its first-use compile would
        break the ladder's zero-recompile-after-warmup guarantee.
        """
        batch = self.process_slice(self._host_batch(self._step))
        self._step += 1
        return batch

    def __next__(self) -> Dict[str, jax.Array]:
        batch = self.next_host()
        if self.batch_sharding is not None:
            return {k: jax.device_put(v, self.batch_sharding[k])
                    for k, v in batch.items()}
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}

    def __iter__(self):
        return self

    def peek_shapes(self) -> Dict[str, tuple]:
        b = self._host_batch(0)
        return {k: v.shape for k, v in b.items()}
