"""Synthetic datasets.

The paper's datasets (Ijcnn1, Webspam, Epsilon) are not redistributable in
this offline environment, so :func:`make_svm_dataset` generates stand-ins
matched on the published statistics — sample count, feature dimension,
sparsity percentage, and an (approximately) linearly separable structure
with label noise so SGD-SVM converges at a realistic, non-trivial accuracy.
Every experiment in the paper therefore has a runnable analog with the same
communication/computation geometry (d-dimensional weight vector, n samples).

``synthetic_lm_batch`` provides deterministic token streams for the LM
training path (zipf-ish marginal over the vocab, shifted-label targets).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SVMDataset:
    """Train / cross-validation / test split, paper Table I layout."""

    name: str
    x_train: np.ndarray        # (n_train, d) float32
    y_train: np.ndarray        # (n_train,)  float32 in {-1, +1}
    x_cv: np.ndarray
    y_cv: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def n_train(self) -> int:
        return self.x_train.shape[0]

    @property
    def features(self) -> int:
        return self.x_train.shape[1]


# name → (n_samples, features, sparsity %) from the paper (Table I / §III)
PAPER_DATASETS: Dict[str, Tuple[int, int, float]] = {
    "ijcnn1": (35_000, 22, 40.91),
    "webspam": (350_000, 254, 99.9),
    "epsilon": (400_000, 2_000, 44.9),
}


def make_svm_dataset(name: str, seed: int = 0, train_fraction: float = 0.8,
                     scale: float = 1.0, label_noise: float = 0.05,
                     n_override: Optional[int] = None) -> SVMDataset:
    """Generate a sparsity/shape-matched stand-in for a paper dataset.

    ``n_override`` shrinks the sample count for fast tests/benchmarks while
    keeping the feature dimension (the quantity that drives communication
    volume) faithful.
    """
    if name not in PAPER_DATASETS:
        raise KeyError(f"unknown dataset {name!r}; known: {sorted(PAPER_DATASETS)}")
    n, d, sparsity_pct = PAPER_DATASETS[name]
    if n_override:
        n = int(n_override)
    rng = np.random.default_rng(seed)

    # ground-truth separating hyperplane
    w_true = rng.normal(size=d).astype(np.float32)
    w_true /= np.linalg.norm(w_true)

    density = max(1e-4, 1.0 - sparsity_pct / 100.0)
    x = rng.normal(scale=scale, size=(n, d)).astype(np.float32)
    if density < 1.0:
        mask = rng.random(size=(n, d)) < density
        # keep at least one nonzero per row so no sample is empty
        empty = ~mask.any(axis=1)
        mask[empty, rng.integers(0, d, size=int(empty.sum()))] = True
        x = x * mask

    margin = x @ w_true
    y = np.where(margin >= 0, 1.0, -1.0).astype(np.float32)
    flip = rng.random(n) < label_noise
    y[flip] = -y[flip]

    n_train = int(train_fraction * n)
    n_rest = n - n_train
    n_cv = n_rest // 2
    idx = rng.permutation(n)
    tr, cv, te = np.split(idx, [n_train, n_train + n_cv])
    return SVMDataset(
        name=name,
        x_train=x[tr], y_train=y[tr],
        x_cv=x[cv], y_cv=y[cv],
        x_test=x[te], y_test=y[te],
    )


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------

def synthetic_lm_batch(step: int, *, global_batch: int, seq_len: int,
                       vocab_size: int, seed: int = 0) -> Dict[str, np.ndarray]:
    """Deterministic (seed, step) → batch. Zipf-distributed tokens.

    Returns ``{"tokens": (B, S) int32, "targets": (B, S) int32}`` where
    targets are tokens shifted left (next-token prediction), final position
    wrapping to token 0 (ignored-index convention is up to the loss).
    """
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    # zipf over a capped support, remapped into the vocab
    raw = rng.zipf(1.2, size=(global_batch, seq_len + 1)).astype(np.int64)
    tokens = (raw % vocab_size).astype(np.int32)
    return {"tokens": tokens[:, :-1], "targets": tokens[:, 1:]}
