from repro.data.synthetic import (
    SVMDataset,
    make_svm_dataset,
    PAPER_DATASETS,
    synthetic_lm_batch,
)
from repro.data.pipeline import DataPipeline

__all__ = [
    "SVMDataset",
    "make_svm_dataset",
    "PAPER_DATASETS",
    "synthetic_lm_batch",
    "DataPipeline",
]
