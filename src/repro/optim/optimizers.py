"""Optimizers as pure functions over param pytrees.

Kept deliberately dependency-free (no optax): ``init_opt_state`` builds the
state pytree, ``apply_updates`` maps ``(grads, state, params, lr) → (new_params,
new_state)``. State leaves mirror param leaves, so the *same logical sharding
axes* apply (``opt_state_axes``) — this is what lets ZeRO-style sharding of
optimizer state fall out of the param sharding rules for free.

Schedules include the paper's ``α = 1/(1+t)`` epoch-decaying rate
(``paper_inverse``), used by the SVM reproduction.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config.base import OptimizerConfig

OptState = Dict[str, Any]


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def make_schedule(cfg: OptimizerConfig) -> Callable[[jax.Array], jax.Array]:
    """step (int32 array) → learning rate (float32 array)."""
    base = cfg.learning_rate

    if cfg.schedule == "constant":
        return lambda step: jnp.float32(base)

    if cfg.schedule == "paper_inverse":
        # the paper's α = 1/(1+t); `t` is the epoch/step counter. `base`
        # rescales (paper uses base=1).
        return lambda step: jnp.float32(base) / (1.0 + step.astype(jnp.float32))

    if cfg.schedule == "cosine":
        warm = max(1, cfg.warmup_steps)
        total = max(cfg.total_steps, warm + 1)

        def sched(step):
            step = step.astype(jnp.float32)
            warm_lr = base * step / warm
            prog = jnp.clip((step - warm) / (total - warm), 0.0, 1.0)
            cos_lr = 0.5 * base * (1.0 + jnp.cos(jnp.pi * prog))
            return jnp.where(step < warm, warm_lr, cos_lr).astype(jnp.float32)

        return sched

    raise ValueError(f"unknown schedule {cfg.schedule!r}")


# ---------------------------------------------------------------------------
# state init
# ---------------------------------------------------------------------------

def init_opt_state(cfg: OptimizerConfig, params) -> OptState:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros_like = lambda: jax.tree.map(
        lambda p: jnp.zeros(p.shape, mdt), params)
    if cfg.name == "sgd":
        return {}
    if cfg.name == "momentum":
        return {"mu": zeros_like()}
    if cfg.name == "adamw":
        return {"mu": zeros_like(), "nu": zeros_like()}
    raise ValueError(f"unknown optimizer {cfg.name!r}")


def opt_state_axes(cfg: OptimizerConfig, param_axes) -> OptState:
    """Logical-axes pytree matching ``init_opt_state`` (mirrors params)."""
    if cfg.name == "sgd":
        return {}
    if cfg.name == "momentum":
        return {"mu": param_axes}
    if cfg.name == "adamw":
        return {"mu": param_axes, "nu": param_axes}
    raise ValueError(cfg.name)


# ---------------------------------------------------------------------------
# update rules
# ---------------------------------------------------------------------------

def _global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def _maybe_clip(grads, clip: float):
    if not clip:
        return grads
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)


def apply_updates(cfg: OptimizerConfig, grads, state: OptState, params,
                  step: jax.Array, lr: Optional[jax.Array] = None):
    """Returns (new_params, new_state). ``step`` is the global step counter."""
    if lr is None:
        lr = make_schedule(cfg)(step)
    grads = _maybe_clip(grads, cfg.grad_clip)

    if cfg.name == "sgd":
        def upd(p, g):
            p32 = p.astype(jnp.float32)
            if cfg.weight_decay:
                p32 = p32 * (1.0 - lr * cfg.weight_decay)
            return (p32 - lr * g.astype(jnp.float32)).astype(p.dtype)
        return jax.tree.map(upd, params, grads), state

    if cfg.name == "momentum":
        def upd(p, g, m):
            m32 = cfg.momentum * m.astype(jnp.float32) + g.astype(jnp.float32)
            p32 = p.astype(jnp.float32)
            if cfg.weight_decay:
                p32 = p32 * (1.0 - lr * cfg.weight_decay)
            return (p32 - lr * m32).astype(p.dtype), m32.astype(m.dtype)
        out = jax.tree.map(upd, params, grads, state["mu"])
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": new_mu}

    if cfg.name == "adamw":
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - cfg.beta1 ** t
        bc2 = 1.0 - cfg.beta2 ** t

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m32 = cfg.beta1 * m.astype(jnp.float32) + (1 - cfg.beta1) * g32
            v32 = cfg.beta2 * v.astype(jnp.float32) + (1 - cfg.beta2) * g32 * g32
            mhat = m32 / bc1
            vhat = v32 / bc2
            p32 = p.astype(jnp.float32)
            if cfg.weight_decay:
                p32 = p32 * (1.0 - lr * cfg.weight_decay)
            p32 = p32 - lr * mhat / (jnp.sqrt(vhat) + cfg.eps)
            return p32.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

        out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
        is_tup = lambda x: isinstance(x, tuple)
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=is_tup)
        new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=is_tup)
        new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=is_tup)
        return new_params, {"mu": new_mu, "nu": new_nu}

    raise ValueError(f"unknown optimizer {cfg.name!r}")
