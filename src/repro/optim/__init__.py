from repro.optim.optimizers import (
    OptState,
    init_opt_state,
    make_schedule,
    opt_state_axes,
    apply_updates,
)

__all__ = [
    "OptState",
    "init_opt_state",
    "make_schedule",
    "opt_state_axes",
    "apply_updates",
]
